"""Disaggregated async prefill stage (ISSUE 3): parity + stall guarantees.

1. With ``disagg_prefill=True`` the engine produces token-for-token
   identical trajectories to the fused refill path (and to one-shot
   generate()) across attention / SSM / hybrid cache families — whole-prompt
   AND chunked prefill, including preempt-at-any-step replay (hypothesis).
2. Decode never blocks on prefill: ``decode_stall_seconds`` is 0 by
   construction in disaggregated mode while the fused baseline books every
   refill as stall.
3. The admission controller's remaining-budget-aware readmission
   re-estimate (preempted rows need less KV headroom) packs tighter.
"""
import random
import time

import jax
import numpy as np
import pytest

from conftest import tiny_lm
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest)
from repro.rollout.prefill import effective_chunk

FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}
_CACHE = {}


def _family(fam: str):
    """(requests, one-shot reference, reusable disagg engine) — built once
    per family and shared by every test/example (requests carry explicit
    seeds, so tokens are independent of engine state and pop order)."""
    if fam not in _CACHE:
        cfg = tiny_lm(FAMILIES[fam])
        params = init_params(jax.random.PRNGKey(0), cfg)
        trees = [init_lora(jax.random.PRNGKey(1), cfg),
                 init_lora(jax.random.PRNGKey(2), cfg)]
        env = make_env("gsm8k")
        rng = random.Random(7)
        reqs = []
        for i in range(3):
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(
                f"t{i % 2}", i % 2, prompt, truth, env,
                max_new_tokens=5 + 2 * i, seed=i))
        ref_eng = RolloutEngine(cfg, params, max_len=64, seed=0)
        ref, _ = ref_eng.generate(reqs, trees)       # uninterrupted oracle
        eng = ContinuousRolloutEngine(cfg, params, max_slots=2,
                                      max_adapters=2, max_len=64, seed=0,
                                      disagg_prefill=True)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        _CACHE[fam] = (cfg, params, reqs, ref, eng)
    return _CACHE[fam]


def _drive(eng, reqs, preempt_step=0, victim=None, max_iters=3000):
    """Pump the engine to completion (optionally preempting `victim` after
    `preempt_step` iterations); completions keyed by request position."""
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, preempted, iters = {}, 0, 0
    deadline = time.monotonic() + 120
    while not eng.idle() and iters < max_iters:
        progressed = eng.step()
        # only productive steps count against the budget: cold-start jit
        # compiles on the async prefill workers spin thousands of
        # no-progress iterations (the 120s deadline guards real hangs)
        if progressed:
            iters += 1
            if iters == preempt_step and victim is not None:
                preempted = eng.preempt_tenant(victim)
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
        if not progressed:
            if time.monotonic() > deadline:
                break
            time.sleep(0.0005)      # waiting on the async prefill stage
    assert len(comps) == len(reqs), (
        f"engine failed to drain: {len(comps)}/{len(reqs)}")
    return comps, preempted


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_disagg_matches_one_shot_token_for_token(fam):
    """Async prefill + scatter splice must reproduce the fused/one-shot
    output bit-for-bit: same forward math, same (key, counter) sampling."""
    _, _, reqs, ref, eng = _family(fam)
    comps, _ = _drive(eng, reqs)
    for i, r in enumerate(ref):
        c = comps[i]
        assert list(c.tokens) == r["tokens"], f"{fam}: token mismatch"
        assert list(c.gen_loss_mask) == r["gen_loss_mask"]
        np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                   atol=1e-5)
    assert eng.stats.splices >= len(reqs)
    assert eng.stats.decode_stall_seconds == 0.0   # decode ran no prefill
    assert eng.stats.prefill_seconds > 0.0         # the workers did


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_chunked_prefill_parity(fam):
    """Long prompts prefilled in fixed-size chunks (state carried across
    chunk boundaries) match the whole-prompt fused path token-for-token.
    Chunk boundaries land mid-prompt for every family (the SSM chunk is
    rounded up to the SSD scan chunk so recurrent state decomposes
    exactly)."""
    cfg = tiny_lm(FAMILIES[fam])
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg)]
    env = make_env("gsm8k")
    rng = random.Random(3)
    reqs = []
    for i in range(4):
        prompt, truth = env.sample_prompt(rng)
        prompt = (prompt * 10)[:40 + 7 * i]        # force multi-chunk
        reqs.append(RolloutRequest("t0", 0, prompt, truth, env,
                                   max_new_tokens=5, seed=i))
    one = RolloutEngine(cfg, params, max_len=96, seed=0)
    ref, _ = one.generate(reqs, trees)
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=96, seed=0, disagg_prefill=True,
                                  prefill_chunk=16, prefill_workers=2)
    eng.set_adapters(0, trees[0])
    assert eng._prefill_chunk_eff == effective_chunk(cfg, 16)
    if cfg.ssm is not None:
        assert eng._prefill_chunk_eff % cfg.ssm.chunk_size == 0
    comps, _ = _drive(eng, reqs)
    for i, r in enumerate(ref):
        c = comps[i]
        assert list(c.tokens) == r["tokens"], f"{fam}: chunked mismatch"
        np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                   atol=1e-5)
    # chunking actually happened: more prefill calls than rows prefilled
    assert eng.stats.prefill_chunks > eng.stats.splices
    eng.shutdown()


def test_preempt_replay_parity_disagg():
    """Hypothesis: preempting at ANY step with the async prefill stage
    yields bit-identical output — the replayed prompt+prefix prefills on a
    worker and splices back with its original per-row counter. (Family
    sweep of the un-preempted path is covered above; the replay machinery
    is family-agnostic host logic.)"""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    _, _, reqs, ref, eng = _family("attention")
    observed = {"n": 0}

    @given(preempt_step=st.integers(1, 14),
           victim=st.sampled_from(["t0", "t1"]))
    @settings(max_examples=8, deadline=None)
    def check(preempt_step, victim):
        comps, preempted = _drive(eng, reqs, preempt_step, victim)
        observed["n"] += preempted
        for i, r in enumerate(ref):
            c = comps[i]
            assert list(c.tokens) == r["tokens"], (
                f"mismatch preempting {victim} at {preempt_step}")
            np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                       atol=1e-5)

    check()
    assert observed["n"] > 0               # preemption+replay exercised
    assert eng.stats.replays > 0
    assert eng.stats.decode_stall_seconds == 0.0


def test_fused_baseline_books_decode_stall():
    """Satellite bugfix: the fused refill books its time as PREFILL-stage
    work and decode-stall, not decode time — and the disaggregated engine
    (same workload) books zero stall."""
    cfg, params, reqs, _, _ = _family("attention")
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    fused = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=2,
                                    max_len=64, seed=0)
    res, st = fused.run_requests(reqs, trees)
    assert all(r is not None for r in res)
    assert st.decode_stall_seconds > 0.0
    assert st.prefill_seconds == pytest.approx(st.decode_stall_seconds)
    assert st.decode_seconds > 0.0         # decode time no longer polluted
    fused.shutdown()


def test_engine_pipeline_accounting():
    """queued()/idle()/active_tenants() see rows anywhere in the prefill
    pipeline (queue, mid-prefill, ready) — the LRU adapter residency relies
    on this to keep a tenant's adapter pinned until its rows splice."""
    _, _, reqs, _, eng = _family("attention")
    idx = {eng.submit(r): i for i, r in enumerate(reqs)}
    assert eng.queued() == len(reqs)
    assert "t0" in eng.active_tenants() and "t1" in eng.active_tenants()
    comps = {}
    deadline = time.monotonic() + 120
    while not eng.idle() and time.monotonic() < deadline:
        eng.step()
        for c in eng.drain_completions():
            comps[idx[c.submit_index]] = c
    assert len(comps) == len(reqs)
    assert eng.queued() == 0
    assert eng.active_tenants() == frozenset()
    pq, rq = eng.queue_depths()
    assert pq == 0 and rq == 0


def test_admission_remaining_budget_reestimate():
    """A preempted task whose rows already decoded most of their budget
    re-admits under a budget its ORIGINAL estimate would not fit."""
    from repro.core.admission import (AdmissionConfig, AdmissionController,
                                      task_state_bytes,
                                      task_state_bytes_remaining)
    from repro.core.manager import TaskSpec
    cfg = tiny_lm("granite-3-2b")
    spec_a = TaskSpec("a", "gsm8k", group_size=2, num_groups=2,
                      max_new_tokens=32)
    spec_b = TaskSpec("b", "gsm8k", group_size=2, num_groups=2,
                      max_new_tokens=32)
    full = task_state_bytes(cfg, spec_a, 32, 2)
    rem = task_state_bytes_remaining(cfg, spec_a, 32, 2, sampled_mean=24.0)
    assert rem < full
    # budget fits one full task + one remaining-estimate task, not two full
    ctl = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=full + rem + 1, strict=True))
    assert ctl.try_admit(spec_a, 32)
    assert ctl.try_admit(spec_b, 32) is False
    ctl.preempt("a")
    assert ctl.try_admit(spec_b, 32)
    # without the re-estimate the preempted task cannot come back ...
    assert ctl.try_readmit("a") is False
    # ... with it (rows at 24/32 sampled) it packs back in
    assert ctl.reestimate_preempted("a", spec_a, 24.0, 32) == rem
    assert ctl.try_readmit("a")
    # re-estimate never RAISES a parked reservation
    ctl2 = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=full, strict=True))
    assert ctl2.try_admit(spec_a, 32)
    ctl2.preempt("a")
    before = ctl2._preempted["a"]
    ctl2.reestimate_preempted("a", spec_a, 0.0, 64)   # longer prompt guess
    assert ctl2._preempted["a"] <= before
    # unknown tasks are a no-op
    assert ctl2.reestimate_preempted("zz", spec_a, 1.0) is None


@pytest.mark.slow
def test_runtime_disagg_end_to_end():
    """MARLaaSRuntime with the async prefill stage: two tenants train to
    completion, per-stage timelines land in the recorder, and the decode
    stream never stalled on prefill."""
    from repro.core.manager import TaskSpec
    from repro.core.metrics import summarize
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48, seed=3,
                                      max_slots=4, disagg_prefill=True,
                                      prefill_workers=2, prefill_chunk=16))
    rt.submit_task(TaskSpec("gsm-a", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=4, target_steps=2))
    rt.submit_task(TaskSpec("gsm-b", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=6, target_steps=2))
    rt.run(timeout_s=300.0)
    assert all(st.done for st in rt.mgr.tasks.values())
    assert rt.cengine.stats.decode_stall_seconds == 0.0
    assert rt.cengine.stats.splices > 0
    out = summarize(rt.mgr, rt.rec)
    assert out["prefill_busy_s"] > 0.0      # worker intervals recorded
    assert out["decode_busy_s"] > 0.0
    assert "prefill_q_mean" in out          # queue-depth timeline sampled
