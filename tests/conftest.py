"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the host's
real single device (the 512-device override belongs to dryrun.py ONLY)."""
import dataclasses

import jax
import pytest

from repro.configs import REGISTRY, reduced
from repro.data import tokenizer as tok


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def tiny(name: str, **over):
    """Reduced fp32 config of an assigned arch (dropless MoE for exactness)."""
    cfg = reduced(REGISTRY[name], dtype="float32", **over)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def tiny_lm(name="granite-3-2b", **over):
    """Tiny config with the rollout tokenizer vocab (for engine tests)."""
    return dataclasses.replace(tiny(name, **over), vocab_size=tok.VOCAB_SIZE)
