"""Deliverable (f): per-architecture smoke tests — a REDUCED config of the
same family runs one forward and one GRPO train step on CPU, asserting
output shapes and no NaNs. (Full configs are exercised via the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from conftest import tiny
from repro.configs import ASSIGNED, shapes_for
from repro.lora.adapters import init_lora
from repro.models import forward_train, init_params
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_opt_state, make_train_step

ARCHS = [c.name for c in ASSIGNED]


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke_forward_and_train_step(name, rng_key):
    cfg = tiny(name)
    p = init_params(rng_key, cfg)
    R, S = 4, 16
    toks = jax.random.randint(rng_key, (R, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(rng_key, (R, 8, cfg.d_model),
                                             jnp.float32)
    logits, aux = forward_train(p, toks, cfg, **kw)
    assert logits.shape == (R, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any(), name

    tc = TrainConfig(group_size=2, adamw=AdamWConfig(lr=1e-3))
    lora = init_lora(rng_key, cfg)
    opt = init_opt_state(cfg, tc, p, lora)
    step = make_train_step(cfg, tc)
    batch = {"tokens": toks,
             "prompt_lens": jnp.full((R,), 4, jnp.int32),
             "total_lens": jnp.full((R,), 12, jnp.int32),
             "rewards": jax.random.uniform(rng_key, (R,))}
    if cfg.family == "encdec":
        batch["enc_embeds"] = kw["enc_embeds"]
    lora2, opt2, metrics = step(p, lora, opt, batch)
    for k, v in metrics.items():
        assert not jnp.isnan(v).any(), (name, k)
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(lora), jax.tree.leaves(lora2)))
    assert jnp.isfinite(moved) and moved > 0, f"{name}: adapters did not move"


@pytest.mark.parametrize("name", ARCHS)
def test_arch_shape_cells_defined(name):
    from repro.configs import REGISTRY
    cfg = REGISTRY[name]
    cells = {s.name for s in shapes_for(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= cells
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in cells        # sub-quadratic archs keep 500k
    else:
        assert "long_500k" not in cells    # full-attention archs skip it
