"""Sharding-spec construction + a full lower/compile of the production step
functions on a degenerate (1,1) host mesh (the 512-way meshes are exercised
by launch/dryrun.py, which owns the device-count override)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY, get_config, shapes_for, ShapeConfig
from repro.launch import shardings as sh, specs as sp
from repro.launch.mesh import make_abstract_mesh, make_host_mesh
from repro.launch.roofline import parse_collectives
from repro.train.sharding import mesh_context


def _fake_mesh_16x16():
    """AbstractMesh stands in for the 256-chip mesh (no devices needed)."""
    return make_abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("name", list(REGISTRY))
def test_param_specs_cover_every_leaf(name):
    cfg = get_config(name)
    mesh = _fake_mesh_16x16()
    shapes = sp.eval_shapes(cfg)
    spec = sh.param_specs(cfg, shapes["params"], mesh)
    flat_shapes = sh._flatten_with_paths(shapes["params"])
    flat_specs = sh._flatten_with_paths(spec)
    assert set(flat_shapes) == set(flat_specs)
    for path, sds in flat_shapes.items():
        ps = flat_specs[path]
        assert isinstance(ps, P)
        assert len(ps) <= len(sds.shape), path
        # divisibility: every sharded dim divides evenly
        for i, ax in enumerate(ps):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            k = int(np.prod([mesh.shape[a] for a in axes]))
            assert sds.shape[i] % k == 0, (path, sds.shape, ps)


@pytest.mark.parametrize("name", ["granite-3-2b", "deepseek-moe-16b",
                                  "mamba2-780m", "zamba2-1.2b"])
def test_cache_and_lora_specs_ranks(name):
    cfg = get_config(name)
    mesh = _fake_mesh_16x16()
    shapes = sp.eval_shapes(cfg)
    lspec = sh.lora_specs(cfg, shapes["lora"], mesh)
    for path, ps in sh._flatten_with_paths(lspec).items():
        sds = sh._flatten_with_paths(shapes["lora"])[path]
        assert len(ps) <= len(sds.shape), path
    serve = sp.serve_specs(cfg, [s for s in shapes_for(cfg)
                                 if s.kind == "decode"][0])
    cspec = sh.cache_specs(cfg, serve["cache"], mesh, 128)
    for path, ps in sh._flatten_with_paths(cspec).items():
        sds = sh._flatten_with_paths(serve["cache"])[path]
        assert len(ps) <= len(sds.shape), (path, ps, sds.shape)


def test_full_step_lowering_on_host_mesh(rng_key):
    """The exact dry-run path (shardings attached, jit, lower, compile) on
    the degenerate host mesh with a reduced config."""
    from conftest import tiny
    cfg = tiny("granite-3-2b")
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 64, 8, "train")
    with mesh_context(mesh):
        shapes = sp.eval_shapes(cfg)
        pspec = sh.param_specs(cfg, shapes["params"], mesh)
        lspec = sh.lora_specs(cfg, shapes["lora"], mesh)
        ospec = sh.opt_specs(lspec)
        batch = sp.train_batch_specs(cfg, shape)
        bspec = sh.batch_specs(batch, mesh, shape.global_batch)
        from repro.train.train_step import TrainConfig, make_train_step
        fn = make_train_step(cfg, TrainConfig(group_size=2, accum_steps=2))
        compiled = jax.jit(fn, donate_argnums=(1, 2)).lower(
            sh.with_shardings(shapes["params"], pspec, mesh),
            sh.with_shardings(shapes["lora"], lspec, mesh),
            sh.with_shardings(shapes["opt"], ospec, mesh),
            sh.with_shardings(batch, bspec, mesh)).compile()
        assert compiled.cost_analysis() is not None


def test_collective_parser():
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[128]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %cp = bf16[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    st = parse_collectives(hlo, default_group=256)
    assert st.count == {"all-gather": 1, "all-reduce": 1,
                        "collective-permute": 1}
    ag = 16 * 512 * 2 * 15 / 16
    ar = 2 * 128 * 4 * 3 / 4
    assert abs(st.per_op["all-gather"] - ag) < 1
    assert abs(st.per_op["all-reduce"] - ar) < 1
    assert st.per_op["collective-permute"] == 64 * 2
