"""Continuous-batching slot engine: parity with one-shot generation,
eviction/starvation behaviour, the sampled-token budget rule, and the
streaming runtime's round assembly + slot metrics."""
import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_lm
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.core.metrics import MetricsRecorder
from repro.data import tokenizer as tok
from repro.envs.base import Env
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest, to_trajectory_batch)


@pytest.fixture(scope="module")
def base():
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _no_eos(eng):
    """Remap sampled EOS to a plain char token so row lengths are exactly
    their budgets (deterministic slot timelines for the tests below)."""
    if hasattr(eng, "_ensure_built"):
        eng._ensure_built()
    elif eng._step_fn is None:
        eng._build(1)
    step = eng._step_fn

    def wrap_step(*a):
        out = step(*a)          # 3-tuple (one-shot) or 4-tuple (continuous)
        nxt = jnp.where(out[0] == tok.EOS, 10, out[0])
        return (nxt,) + tuple(out[1:])

    eng._step_fn = wrap_step
    if getattr(eng, "_refill_fn", None) is not None:
        refill = eng._refill_fn

        def wrap_refill(*a):
            first, lp, cache, state = refill(*a)
            first = jnp.where(first == tok.EOS, 10, first)
            state = (jnp.where(state[0] == tok.EOS, 10, state[0]),) \
                + tuple(state[1:])
            return first, lp, cache, state

        eng._refill_fn = wrap_refill
    if getattr(eng, "_first_fn", None) is not None:
        first_fn = eng._first_fn

        def wrap_first(*a):
            s, lp = first_fn(*a)
            return jnp.where(s == tok.EOS, 10, s), lp

        eng._first_fn = wrap_first


def test_continuous_matches_one_shot_token_for_token(base):
    """Slot refill must preserve per-row KV cache and adapter-id routing:
    continuous output (3 slots, 6 queued mixed-length requests across 2
    adapters) must equal one-shot generate() token-for-token."""
    cfg, params = base
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    env = make_env("gsm8k")
    rng = random.Random(0)
    reqs = []
    for i in range(6):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=4 + 3 * (i % 3), seed=i))
    one = RolloutEngine(cfg, params, max_len=64, seed=0)
    res1, _ = one.generate(reqs, trees)
    cont = ContinuousRolloutEngine(cfg, params, max_slots=3, max_adapters=2,
                                   max_len=64, seed=0)
    res2, st2 = cont.run_requests(reqs, trees)
    assert st2.prefills == 6         # every request went through a slot
    assert st2.refills >= 2          # and slots were refilled after eviction
    assert st2.completions == 6
    for a, b in zip(res1, res2):
        assert a["tokens"] == b["tokens"]
        assert a["gen_loss_mask"] == b["gen_loss_mask"]
        np.testing.assert_allclose(a["gen_logprobs"], b["gen_logprobs"],
                                   atol=1e-5)
    # rewards (and thus training signal) identical too
    tb1 = to_trajectory_batch(res1, "t0", 0, 1)
    tb2 = to_trajectory_batch(res2, "t0", 0, 1)
    np.testing.assert_array_equal(tb1.rewards, tb2.rewards)


def test_short_tenant_not_starved_by_long_tenant(base):
    """Eviction/refill: a tenant's long rows cannot block another tenant's
    short rows — freed slots cycle through the short queue while the long
    rows keep decoding."""
    cfg, params = base
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    env = make_env("gsm8k")
    rng = random.Random(1)
    eng = ContinuousRolloutEngine(cfg, params, max_slots=3, max_adapters=2,
                                  max_len=96, seed=0)
    _no_eos(eng)
    for i, tree in enumerate(trees):
        eng.set_adapters(i, tree)
    for i in range(2):                      # tenant A: long rows, queued first
        prompt, truth = env.sample_prompt(rng)
        eng.submit(RolloutRequest("long", 0, prompt, truth, env,
                                  max_new_tokens=48))
    for i in range(6):                      # tenant B: short rows, queued after
        prompt, truth = env.sample_prompt(rng)
        eng.submit(RolloutRequest("short", 1, prompt, truth, env,
                                  max_new_tokens=4))
    comps = eng.drain(deadline_s=120)
    assert len(comps) == 8
    assert all(c.finish_reason == "budget" for c in comps)
    long_steps = [c.finished_step for c in comps if c.task_id == "long"]
    short_steps = [c.finished_step for c in comps if c.task_id == "short"]
    # every short row (even the last-queued) finished before any long row
    assert max(short_steps) < min(long_steps), (short_steps, long_steps)
    # slots cycled: 8 rows streamed through 3 slots
    assert eng.stats.prefills == 8
    # decode never drained while refilling: the long rows' 48-token budget
    # bounds the whole run (short rows ride along in freed slots)
    assert eng.stats.decode_steps < 48 + 6 * 4


def test_forced_tool_tokens_do_not_consume_budget(base):
    """A long force-fed tool response must not eat the sampling budget: the
    row still samples its answer after ENDRESP (old code terminated at
    max_new_tokens total length, truncating the answer)."""
    cfg, params = base

    class LongToolEnv(Env):
        name = "longtool"
        is_agentic = True
        env_latency_mean = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("abc?"), "42"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return tok.encode("0123456789" * 2)      # 20-token response

    env = LongToolEnv()
    eng = RolloutEngine(cfg, params, max_len=96, seed=0)
    eng._build(1)
    _no_eos(eng)
    orig_step = eng._step_fn
    count = {"n": 0}

    def forced_call_step(*args):
        nxt, lp, cache = orig_step(*args)
        count["n"] += 1
        if count["n"] == 1:                  # first decode step emits CALL
            nxt = jnp.full_like(nxt, tok.CALL)
        return nxt, lp, cache

    eng._step_fn = forced_call_step
    reqs = [RolloutRequest("lt", 0, [tok.BOS] + tok.encode("abc?"), "42", env,
                           max_new_tokens=4)]
    res, _ = eng.generate(reqs, [init_lora(jax.random.PRNGKey(1), cfg)])
    mask = res[0]["gen_loss_mask"]
    toks = res[0]["tokens"][res[0]["prompt_len"]:]
    assert tok.RESP in toks and tok.ENDRESP in toks
    # full budget of SAMPLED tokens, despite 22 forced tokens in between
    assert sum(1 for m in mask if m == 1.0) == 4
    # and the sampled answer tokens sit AFTER the tool response
    end = toks.index(tok.ENDRESP)
    assert len(toks) > end + 1
    assert all(m == 1.0 for m in mask[end + 1:])


def test_preempt_replay_matches_uninterrupted(base):
    """Admission-driven preemption: evicting a tenant's resident rows
    mid-decode and prefix-replaying them into later slots must reproduce
    the uninterrupted run token-for-token (logprobs included)."""
    cfg, params = base
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    env = make_env("gsm8k")
    rng = random.Random(3)
    reqs = []
    for i in range(4):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=6 + 2 * i, seed=i))
    one = RolloutEngine(cfg, params, max_len=64, seed=0)
    ref, _ = one.generate(reqs, trees)

    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=2,
                                  max_len=64, seed=0)
    for i, tree in enumerate(trees):
        eng.set_adapters(i, tree)
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, iters = {}, 0
    preempted = 0
    while not eng.idle() and iters < 400:
        eng.step()
        iters += 1
        if iters in (3, 7):                   # preempt both tenants mid-run
            preempted += eng.preempt_tenant(f"t{iters % 2}")
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
    assert preempted > 0 and eng.stats.preemptions == preempted
    assert eng.stats.replays == preempted     # every victim replayed
    assert eng.stats.replay_tokens > 0
    assert len(comps) == len(reqs)
    for i, r in enumerate(ref):
        assert list(comps[i].tokens) == r["tokens"]
        assert list(comps[i].gen_loss_mask) == r["gen_loss_mask"]
        np.testing.assert_allclose(comps[i].gen_logprobs, r["gen_logprobs"],
                                   atol=1e-5)


def test_lru_adapter_streaming_many_tenants(base):
    """8 tenants stream through 2 stacked-LoRA slots: the LRU residency map
    evicts idle tenants' adapters so tenant count ≫ max_adapters completes,
    and every row decodes under its own tenant's adapter routing."""
    from repro.lora.multilora import AdapterResidency
    cfg, params = base
    n_tenants = 8
    trees = [init_lora(jax.random.PRNGKey(10 + t), cfg)
             for t in range(n_tenants)]
    env = make_env("gsm8k")
    rng = random.Random(5)
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=2,
                                  max_len=64, seed=0)
    res = AdapterResidency(2, eng.set_adapters)
    todo = list(range(n_tenants))
    done = {}
    iters = 0
    while (todo or not eng.idle()) and iters < 2000:
        iters += 1
        # submit a tenant's row only once its adapter is resident; tenants
        # with rows in flight are pinned
        for t in list(todo):
            slot = res.acquire(f"t{t}", trees[t],
                               in_use=lambda x: x in eng.active_tenants())
            if slot is None:
                break
            prompt, truth = env.sample_prompt(rng)
            eng.submit(RolloutRequest(f"t{t}", slot, prompt, truth, env,
                                      max_new_tokens=4, seed=t))
            todo.remove(t)
        eng.step()
        for c in eng.drain_completions():
            done[c.task_id] = c
    assert len(done) == n_tenants
    assert res.evictions >= n_tenants - 2     # adapters actually cycled
    assert all(c.finish_reason in ("eos", "budget") for c in done.values())


def test_runtime_admission_preemption_lifecycle(base):
    """Strict admission + priorities end-to-end: a high-priority task
    arriving while a low-priority one runs preempts it (bytes released,
    rows evicted on the rollout thread, status preempted); the victim is
    later re-admitted and both finish."""
    from repro.core.admission import AdmissionConfig, task_state_bytes
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    cfg, params = base
    lo = TaskSpec("lo", "gsm8k", group_size=2, num_groups=1,
                  max_new_tokens=6, target_steps=2, priority=0)
    hi = TaskSpec("hi", "gsm8k", group_size=2, num_groups=1,
                  max_new_tokens=6, target_steps=2, priority=3)
    budget = task_state_bytes(cfg, lo, 32) * 1.5    # fits ONE task only
    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48, seed=5,
                                      max_slots=4),
                        acfg=AdmissionConfig(memory_budget_bytes=budget,
                                             strict=True))
    rt.submit_task(lo)
    # hi arrives once lo holds the budget: submit from a timer so the
    # driver's admission tick must preempt to place it
    timer = threading.Timer(0.5, lambda: rt.submit_task(hi))
    timer.start()
    try:
        rt.run(timeout_s=300.0)
    finally:
        timer.cancel()
    assert rt.mgr.tasks["lo"].done and rt.mgr.tasks["hi"].done
    # the high-priority newcomer displaced the admitted low-priority task
    assert rt.mgr.tasks["lo"].preempt_count >= 1
    assert rt.rec.counters.get("readmissions", 0) >= 1
    # and nothing leaked: all reservations settled at the end
    assert rt.admission.preempted() == []


def test_slot_utilization_metric():
    rec = MetricsRecorder({"rollout": 1})
    rec.record_slot_sample(0.0, 2, 4)
    rec.record_slot_sample(1.0, 4, 4)
    rec.record_slot_sample(3.0, 0, 4)
    # 1s at 2/4 + 2s at 4/4 over 3s = (0.5 + 2.0) / 3
    assert abs(rec.slot_utilization_pct() - 100.0 * 2.5 / 3.0) < 1e-9
    empty = MetricsRecorder({"rollout": 1})
    assert empty.slot_utilization_pct() == 0.0


def test_manager_tracks_inflight_rows():
    mgr = MultiTaskManager()
    mgr.submit(TaskSpec("a", "gsm8k", group_size=2, num_groups=1))
    mgr.admit("a")
    mgr.rollout_started("a", 2)
    assert mgr.inflight_rows() == {"a": 2}
    mgr.rollout_row_done("a")
    mgr.rollout_row_done("a")
    assert mgr.inflight_rows() == {}
    assert mgr.tasks["a"].rollout_rows_total == 2


def test_streaming_worker_assembles_rounds(base):
    """The runtime's streaming rollout worker feeds the slot engine and
    assembles per-(task, version) rounds into Q_buffer without a trainer —
    cross-tenant slot sharing shows up in the fused decode interval and the
    slot-occupancy samples."""
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    cfg, params = base
    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48, seed=3,
                                      max_slots=6))
    rt.submit_task(TaskSpec("gsm-a", "gsm8k", group_size=2, num_groups=2,
                            max_new_tokens=4, target_steps=1))
    rt.submit_task(TaskSpec("gsm-b", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=6, target_steps=1))
    for tid in list(rt.mgr.tasks):
        rt.mgr.admit(tid)
    worker = threading.Thread(target=rt._rollout_loop, daemon=True)
    worker.start()
    deadline = time.monotonic() + 120
    while time.monotonic() < deadline and len(rt.mgr.q_buffer) < 2:
        time.sleep(0.01)
    rt._stop.set()
    worker.join(timeout=10)
    assert rt.error is None
    assert len(rt.mgr.q_buffer) == 2
    seen = {}
    for tb in rt.mgr.q_buffer:
        seen[tb.task_id] = tb
        assert tb.version == 0
        assert "finish_reasons" in tb.meta
    assert set(seen) == {"gsm-a", "gsm-b"}
    assert seen["gsm-a"].num_rows == 4 and seen["gsm-b"].num_rows == 2
    # GRPO groups are contiguous rows sharing a prompt: eviction order must
    # not scramble them (rows [g*G,(g+1)*G) were submitted with one prompt)
    tb = seen["gsm-a"]
    for g in range(tb.num_groups):
        a, b = g * 2, g * 2 + 1
        pl = min(tb.prompt_lens[a], tb.prompt_lens[b])
        assert tb.prompt_lens[a] == tb.prompt_lens[b]
        np.testing.assert_array_equal(tb.tokens[a, :pl], tb.tokens[b, :pl])
    assert rt.mgr.inflight_rows() == {}            # all rows accounted for
    assert rt.rec.slot_samples and rt.rec.slot_utilization_pct() > 0
    fused = [iv for iv in rt.rec.intervals if iv.phase == "decode"]
    assert any("+" in iv.task_id for iv in fused), \
        "tenants never shared the slot pool"
