"""Global copy-on-write prefix cache (ISSUE 8).

1. PrefixIndex unit behavior: insert/match/match_full/LRU eviction over
   page-aligned chunks + exact-remainder tail nodes.
2. GRPO-group sharing parity: N same-prompt siblings share the leader's
   prompt pages (tail included), fork copy-on-write on first divergent
   decode write, and every row is token-for-token identical to the
   private-pages baseline — including siblings preempted mid-fork
   (hypothesis: preempt at ANY step; deterministic fallback runs always).
3. Radix prefix reuse: distinct prompts sharing a page-aligned template
   prefill only their suffix, bit-identical to the baseline.
4. Device-resident snapshots: park/preempt of in-pool rows moves ZERO
   bytes to host (snapshots == 0 for attention), resume is a block-table
   splice (device_resident_resumes > 0), and host spill under pool
   pressure still completes identically.
5. Response-prefill fusion: replay-mode resumes fold the forced RESP
   block into one prefill call, identical output.
6. SSM/hybrid: the prefix cache degrades to a no-op for recurrent
   families without breaking parity.

Every drive loop runs ``eng.check_page_invariants()`` — exact refcount
conservation across slots, device-parked rows, and radix nodes — so COW
can't leak or double-free silently.
"""
import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests skip without hypothesis; the rest still run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
requires_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                         reason="hypothesis not installed")

from conftest import tiny_lm
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest
from repro.rollout.kvcache import PagePool, PrefixIndex

FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}
# 14-token template: with page_size 8 the padded prompts span ≥2 full
# pages + a partial tail — the shapes the three sharing levels need
TEMPLATE = [5, 9, 4, 11, 7, 3, 8, 2, 6, 10, 12, 5, 9, 4]


# ===========================================================================
# 1. PrefixIndex unit behavior
# ===========================================================================

def test_prefix_index_match_and_tail():
    idx = PrefixIndex(page_size=4)
    newly = idx.insert(0, list(range(10)), [5, 6], tail_page=7)
    assert sorted(newly) == [5, 6, 7]
    # exact whole-sequence hit returns the tail; the page-aligned prefix
    # of the same entry is an exact hit WITHOUT the tail
    assert idx.match_full(0, list(range(10))) == ([5, 6], 7)
    assert idx.match_full(0, list(range(8))) == ([5, 6], None)
    assert idx.match_full(0, list(range(9))) is None     # tail key differs
    assert idx.match(0, list(range(9)), max_tokens=8) == [5, 6]
    assert idx.match(1, list(range(10))) == []           # per-tenant
    # re-insert dedups: nothing newly referenced
    assert idx.insert(0, list(range(10)), [5, 6], tail_page=7) == []
    assert idx.held_pages == 3
    assert idx.refcounts() == {5: 1, 6: 1, 7: 1}


def test_prefix_index_lru_and_invalidate():
    idx = PrefixIndex(page_size=4)
    idx.insert(0, list(range(8)), [1, 2])
    idx.match(0, list(range(4)))             # touch the first chunk
    idx.insert(1, list(range(4)), [3])
    dropped = idx.pop_lru(1)                 # evicts a cold leaf first
    assert dropped and idx.held_pages == 3 - len(dropped)
    idx2 = PrefixIndex(page_size=4)
    idx2.insert(0, list(range(8)), [1, 2])
    idx2.insert(1, list(range(4)), [3])
    rel = idx2.invalidate(adapter=0)
    assert sorted(rel) == [1, 2] and idx2.held_pages == 1


# ===========================================================================
# shared drive helpers
# ===========================================================================

def _drive(eng, reqs, preempt_at=(), victims=("t0", "t1")):
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, it = {}, 0
    deadline = time.monotonic() + 120
    while not eng.idle() and time.monotonic() < deadline:
        progressed = eng.step()
        it += 1
        if it in preempt_at:
            for v in victims:
                eng.preempt_tenant(v)
        eng.check_page_invariants()
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
        if not progressed:
            time.sleep(0.0005)
    assert len(comps) == len(reqs), f"drained {len(comps)}/{len(reqs)}"
    eng.check_page_invariants()
    return comps


def _assert_parity(a, b, ctx=""):
    for i in sorted(a):
        assert list(a[i].tokens) == list(b[i].tokens), (
            f"{ctx}: token mismatch row {i}: "
            f"{list(a[i].tokens)} vs {list(b[i].tokens)}")
        assert list(a[i].gen_loss_mask) == list(b[i].gen_loss_mask)
        np.testing.assert_allclose(a[i].gen_logprobs, b[i].gen_logprobs,
                                   atol=1e-5)


def _group_reqs(cfg_name="gsm8k", n=6, max_new=8, seed=7):
    """A GRPO group: n same-prompt rows (template-padded past 2 pages)."""
    env = make_env(cfg_name)
    rng = random.Random(seed)
    prompt, truth = env.sample_prompt(rng)
    prompt = TEMPLATE + prompt
    return [RolloutRequest("t0", 0, prompt, truth, env,
                           max_new_tokens=max_new, seed=i)
            for i in range(n)]


def _engine(cfg, params, trees, prefix_cache, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("kv_page_size", 8)
    eng = ContinuousRolloutEngine(cfg, params, max_adapters=len(trees),
                                  seed=0, paged_kv=True,
                                  prefix_cache=prefix_cache, **kw)
    for i, tree in enumerate(trees):
        eng.set_adapters(i, tree)
    return eng


@pytest.fixture(scope="module")
def attn():
    cfg = tiny_lm(FAMILIES["attention"])
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg)]
    return cfg, params, trees


# ===========================================================================
# 2. GRPO-group sharing + COW forks
# ===========================================================================

def test_grpo_group_cow_parity(attn):
    """Six same-prompt siblings through three slots: all but the leader
    install via the shared-prefix path with ZERO prompt prefill, the
    first divergent decode write COW-forks the shared tail page, and
    every row matches the private-pages baseline bit-for-bit."""
    cfg, params, trees = attn
    reqs = _group_reqs()
    base = _drive(_engine(cfg, params, trees, False), reqs)
    eng = _engine(cfg, params, trees, True)
    shared = _drive(eng, reqs)
    _assert_parity(base, shared, "grpo-cow")
    st = eng.stats
    assert st.prefix_hits >= len(reqs) - 1
    assert st.cow_forks >= 1                 # the tail page genuinely forks
    # siblings prefill only their (empty) suffix: ≥2x prefill-token cut
    base_pf = sum(len(r.prompt) for r in reqs)
    assert st.prefill_tokens * 2 <= base_pf
    # each hit books the page-aligned shared span (tail recomputes only
    # for the first-token logits, with zero cache writes)
    start = len(reqs[0].prompt) // 8 * 8
    assert st.prefix_hit_tokens == (len(reqs) - 1) * start
    # at idle only the radix-retained prompt pages remain
    assert eng._pages.used_pages == eng._prefix_idx.held_pages > 0
    assert eng.page_stats()["kv_prefix_pages"] > 0


def test_grpo_group_preempt_mid_fork(attn):
    """Siblings preempted/parked WHILE sharing pages: the device-resident
    park retains shared refcounts, resume re-splices, and parity holds."""
    cfg, params, trees = attn
    reqs = _group_reqs(n=5, max_new=10)
    base = _drive(_engine(cfg, params, trees, False), reqs)
    eng = _engine(cfg, params, trees, True, max_slots=2)
    shared = _drive(eng, reqs, preempt_at=(3, 9, 15), victims=("t0",))
    _assert_parity(base, shared, "preempt-mid-fork")
    st = eng.stats
    assert st.prefix_hits >= 1 and st.cow_forks >= 1
    assert st.device_resident_resumes > 0
    assert st.snapshots == 0                 # zero host snapshot bytes
    assert st.snapshot_drops == 0
    assert eng._snap_store.bytes_used == 0


@requires_hypothesis
def test_grpo_group_cow_parity_property(attn):
    """Preempting the group at ANY step — mid-prefill, mid-fork, after
    divergence — never breaks token parity or page conservation."""
    cfg, params, trees = attn
    reqs = _group_reqs(n=4, max_new=8)
    base = _drive(_engine(cfg, params, trees, False), reqs)
    eng = _engine(cfg, params, trees, True, max_slots=2)

    @given(preempt_step=st.integers(1, 12))
    @settings(max_examples=6, deadline=None)
    def check(preempt_step):
        shared = _drive(eng, reqs, preempt_at=(preempt_step,),
                        victims=("t0",))
        _assert_parity(base, shared, f"property@{preempt_step}")

    check()
    assert eng.stats.prefix_hits > 0 and eng.stats.cow_forks > 0


# ===========================================================================
# 3. radix prefix reuse across DISTINCT prompts
# ===========================================================================

def test_radix_suffix_prefill_parity(attn):
    """Four rows with different questions behind one page-aligned
    template: later rows match the cached template pages and prefill only
    their suffix — same tokens as the baseline, prefill_tokens down by
    exactly the matched length."""
    cfg, params, trees = attn
    env = make_env("gsm8k")
    rng = random.Random(3)
    reqs = []
    for i in range(4):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest("t0", 0, TEMPLATE + [2 + i] + prompt,
                                   truth, env, max_new_tokens=6, seed=i))
    base = _drive(_engine(cfg, params, trees, False, max_slots=2), reqs)
    eng = _engine(cfg, params, trees, True, max_slots=2)
    shared = _drive(eng, reqs)
    _assert_parity(base, shared, "radix")
    st = eng.stats
    assert st.prefix_hits > 0
    base_pf = sum(len(r.prompt) for r in reqs)
    assert st.prefill_tokens == base_pf - st.prefix_hit_tokens
    assert st.prefix_hit_tokens > 0


# ===========================================================================
# 4. device-resident snapshots (+ spill tier under pressure)
# ===========================================================================

@pytest.fixture
def biased_sampler():
    """Deterministic CALL pattern at fixed per-row counters, restored
    after the test (the bench_env_stage trick)."""
    import repro.rollout.engine as eng_mod
    import repro.rollout.prefill as pf_mod
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = (counters == 1) | (counters == 6)
        return jnp.where(hit, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    yield
    pf_mod._sample_rows = orig
    eng_mod._sample_rows = orig


def _agentic_reqs(n=4, hops=2):
    env = make_env("hopsearch", kb_size=8, hops=hops, seed=0)
    env.env_latency_mean = 0.0
    rng = random.Random(7)
    reqs = []
    for i in range(n):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=10, seed=i))
    return reqs


@pytest.mark.parametrize("disagg", [False, True])
def test_device_resident_park_zero_host_bytes(attn, disagg, biased_sampler):
    """Agentic park/resume with the prefix cache: rows park as pure
    retains (ZERO host snapshot bytes — snapshots == 0, arena empty,
    snapshot_drops unchanged), resume as block-table splices
    (device_resident_resumes > 0), identical to the host-snapshot
    baseline on both fill paths."""
    cfg, params, _ = attn
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    reqs = _agentic_reqs()
    base_eng = _engine(cfg, params, trees, False, max_slots=2, max_len=96,
                       kv_page_size=16, env_stage=True, env_workers=2,
                       disagg_prefill=disagg)
    base = _drive(base_eng, reqs, preempt_at=(6, 14))
    assert base_eng.stats.snapshots > 0      # baseline round-trips host
    base_eng.shutdown()
    eng = _engine(cfg, params, trees, True, max_slots=2, max_len=96,
                  kv_page_size=16, env_stage=True, env_workers=2,
                  disagg_prefill=disagg)
    shared = _drive(eng, reqs, preempt_at=(6, 14))
    _assert_parity(base, shared, f"dev-park disagg={disagg}")
    st = eng.stats
    assert st.parks > 0 and st.resumes > 0
    assert st.device_resident_resumes > 0
    assert st.snapshots == 0 and st.snapshot_drops == 0
    assert eng._snap_store.bytes_used == 0
    assert st.replay_tokens == 0
    eng.shutdown()


def test_device_parked_spill_under_pool_pressure(attn, biased_sampler):
    """A pool too small to hold parked rows + fresh prefills spills the
    oldest device-parked row to the host snapshot tier (or replay) —
    rows still complete with identical tokens, pages conserve."""
    cfg, params, _ = attn
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    reqs = _agentic_reqs(n=4)
    base = _drive(_engine(cfg, params, trees, False, max_slots=2,
                          max_len=96, kv_page_size=16, env_stage=True,
                          env_workers=2), reqs)
    # 12 pages of 16 = 2 full slots + scraps: parked rows can't all stay
    eng = _engine(cfg, params, trees, True, max_slots=2, max_len=96,
                  kv_page_size=16, kv_pool_pages=12, env_stage=True,
                  env_workers=2)
    shared = _drive(eng, reqs)
    _assert_parity(base, shared, "spill")
    assert eng.stats.parks > 0
    assert eng._pages.used_pages == (eng._prefix_idx.held_pages
                                     if eng._prefix_idx else 0)
    eng.shutdown()


# ===========================================================================
# 5. response-prefill fusion (replay-mode resumes)
# ===========================================================================

@pytest.mark.parametrize("disagg", [False, True])
def test_response_prefill_fusion_parity(attn, disagg, biased_sampler):
    """resume_restore=False forces every resume through the replay
    prefill: the forced RESP…ENDRESP block folds into that call
    (fused_forced_tokens > 0) with bit-identical tokens AND logprobs to
    the step-wise baseline (prefix cache off, same replay mode)."""
    cfg, params, _ = attn
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    reqs = _agentic_reqs()
    engines = {}
    outs = {}
    for mode, pc in (("base", False), ("fused", True)):
        eng = _engine(cfg, params, trees, pc, max_slots=2, max_len=96,
                      kv_page_size=16, env_stage=True, env_workers=2,
                      disagg_prefill=disagg, resume_restore=False)
        outs[mode] = _drive(eng, reqs)
        engines[mode] = eng
        eng.shutdown()
    _assert_parity(outs["base"], outs["fused"], f"fusion disagg={disagg}")
    assert engines["fused"].stats.fused_forced_tokens > 0
    # fusion also runs on the base engine (it is a paged-mode feature,
    # not a prefix-cache feature) — both must fold the forced block
    assert engines["base"].stats.fused_forced_tokens > 0


# ===========================================================================
# 6. recurrent families: prefix cache degrades to a safe no-op
# ===========================================================================

@pytest.mark.parametrize("fam", ["ssm", "hybrid"])
def test_recurrent_families_unaffected(fam):
    """SSM/hybrid rows carry recurrent state with no shareable paged
    form: radix/group sharing must stay OFF (no hits, no forks) and the
    prefix_cache knob must not change a single token."""
    cfg = tiny_lm(FAMILIES[fam])
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg)]
    reqs = _group_reqs(n=4, max_new=6)
    base = _drive(_engine(cfg, params, trees, False), reqs)
    eng = _engine(cfg, params, trees, True)
    shared = _drive(eng, reqs, preempt_at=(4,), victims=("t0",))
    _assert_parity(base, shared, fam)
    assert eng.stats.prefix_hits == 0
    assert eng.stats.cow_forks == 0
