"""GRPO substrate: advantages, clip loss, chunked logprobs, AdamW."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.grpo import (group_advantages, grpo_loss,
                           token_logprobs_chunked)
from repro.kernels import ref
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, global_norm)

KEY = jax.random.PRNGKey(0)


def test_group_advantages_normalized():
    r = jax.random.uniform(KEY, (24,))
    adv = group_advantages(r, 8)
    g = np.asarray(adv).reshape(3, 8)
    np.testing.assert_allclose(g.mean(1), 0.0, atol=1e-5)
    assert (np.abs(g.std(1) - 1.0) < 0.05).all()


def test_group_advantages_constant_group_is_zero():
    r = jnp.ones((8,)) * 0.7
    adv = group_advantages(r, 4)
    np.testing.assert_allclose(np.asarray(adv), 0.0, atol=1e-3)


def test_grpo_gradient_direction():
    """Positive advantage ⇒ gradient pushes logprob up (and vice versa)."""
    lp = jnp.log(jnp.full((2, 4), 0.3))
    adv = jnp.array([1.0, -1.0])
    mask = jnp.ones((2, 4))

    def loss(x):
        return grpo_loss(x, jax.lax.stop_gradient(x), adv, mask).loss

    g = jax.grad(loss)(lp)
    assert (np.asarray(g[0]) < 0).all()     # minimize ⇒ raise lp of +adv row
    assert (np.asarray(g[1]) > 0).all()


def test_grpo_clip_bounds_update():
    """Beyond the clip range the objective gradient must vanish."""
    old = jnp.zeros((1, 4))
    new = jnp.full((1, 4), 1.0)             # ratio e^1 ≈ 2.7 > 1+eps
    adv = jnp.array([1.0])
    mask = jnp.ones((1, 4))

    def loss(x):
        return grpo_loss(x, old, adv, mask, clip_eps=0.2).loss

    g = jax.grad(loss)(new)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-6)


def test_grpo_mask_excludes_positions():
    lp_a = jnp.array([[0.0, -1.0, -9.0, -9.0]])
    lp_b = jnp.array([[0.0, -1.0, -2.0, -3.0]])
    adv = jnp.array([0.5])
    mask = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    la = grpo_loss(lp_a, lp_a, adv, mask).loss
    lb = grpo_loss(lp_b, lp_b, adv, mask).loss
    assert float(abs(la - lb)) < 1e-7


def test_kl_k3_nonnegative():
    new = jax.random.normal(KEY, (3, 5)) * 0.1 - 1.0
    refp = new + jax.random.normal(jax.random.PRNGKey(1), (3, 5)) * 0.3
    out = grpo_loss(new, jax.lax.stop_gradient(new), jnp.zeros((3,)),
                    jnp.ones((3, 5)), ref_logprobs=refp, kl_coef=0.1)
    assert float(out.kl) >= 0.0


def test_token_logprobs_chunked_matches_ref():
    B, S, d, V = 2, 16, 24, 60
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.3
    t = jax.random.randint(ks[2], (B, S), 0, V)
    lp, ent = token_logprobs_chunked(h, w, t, chunk=4)
    want_lp, want_ent = ref.token_logprob_ref(h, w, t)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want_lp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want_ent),
                               rtol=1e-5, atol=1e-5)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.1, grad_clip=0.0)
    st = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st, _ = adamw_update(params, g, st, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(n) > 100.0
