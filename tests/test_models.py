"""Model-zoo correctness: every family's teacher-forced forward must agree
with its prefill+decode cached path, and LoRA batched/single paths must be
exactly equivalent."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from conftest import tiny
from repro.configs import REGISTRY
from repro.lora.adapters import (batched_ctx, init_lora, single_ctx,
                                 stack_adapters)
from repro.models import (decode_step, forward_seq, forward_train, init_cache,
                          init_params)

FAMILIES = ["granite-3-2b", "deepseek-moe-16b", "mamba2-780m", "zamba2-1.2b",
            "gemma2-27b", "seamless-m4t-large-v2", "chameleon-34b"]


def _enc_kw(cfg, key, B):
    if cfg.family == "encdec":
        return {"enc_embeds": jax.random.normal(key, (B, 8, cfg.d_model),
                                                jnp.float32)}
    return {}


@pytest.mark.parametrize("name", FAMILIES)
def test_decode_matches_teacher_forced(name, rng_key):
    cfg = tiny(name)
    p = init_params(rng_key, cfg)
    B, S = 2, 17
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    kw = _enc_kw(cfg, rng_key, B)
    full, _ = forward_train(p, toks, cfg, **kw)
    assert full.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(full).any()

    cache = init_cache(cfg, B, 32, enc_len=8, dtype=jnp.float32)
    _, cache, _ = forward_seq(p, toks[:, :S - 1], cfg, None, cache, **kw)
    cache["pos"] = jnp.full((B,), S - 1, jnp.int32)
    logits, cache = decode_step(p, toks[:, S - 1], cache, cfg)
    err = float(jnp.max(jnp.abs(logits - full[:, S - 1])))
    assert err < 2e-3, f"{name}: decode/teacher-forced mismatch {err}"


@pytest.mark.parametrize("name", ["granite-3-2b", "mamba2-780m",
                                  "zamba2-1.2b", "deepseek-moe-16b"])
def test_multi_lora_batched_equals_single(name, rng_key):
    cfg = tiny(name)
    p = init_params(rng_key, cfg)
    B, S = 4, 12
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    l0 = init_lora(jax.random.PRNGKey(3), cfg)
    mk = lambda s: jax.tree.map(
        lambda t: jax.random.normal(jax.random.PRNGKey(s), t.shape, t.dtype) * 0.05, l0)
    l1, l2 = mk(5), mk(6)
    s1, _ = forward_train(p, toks, cfg, single_ctx(l1, cfg))
    s2, _ = forward_train(p, toks, cfg, single_ctx(l2, cfg))
    ids = jnp.array([0, 1, 1, 0])
    batched, _ = forward_train(p, toks, cfg,
                               batched_ctx(stack_adapters([l1, l2]), ids, cfg))
    expect = jnp.stack([s1[0], s2[1], s2[2], s1[3]])
    assert float(jnp.max(jnp.abs(batched - expect))) < 1e-5


def test_lora_v0_is_identity(rng_key):
    cfg = tiny("granite-3-2b")
    p = init_params(rng_key, cfg)
    toks = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab_size)
    base, _ = forward_train(p, toks, cfg)
    l0 = init_lora(rng_key, cfg)   # b zero-init
    with_l, _ = forward_train(p, toks, cfg, single_ctx(l0, cfg))
    assert float(jnp.max(jnp.abs(base - with_l))) < 1e-6


def test_gemma2_local_global_masks_differ(rng_key):
    """Sliding-window layers must actually mask (differ from global)."""
    cfg = tiny("gemma2-27b")
    assert cfg.local_global_period == 2 and cfg.sliding_window > 0
    glob = dataclasses.replace(cfg, sliding_window=0, local_global_period=0)
    p = init_params(rng_key, cfg)
    S = cfg.sliding_window + 16
    toks = jax.random.randint(rng_key, (1, S), 0, cfg.vocab_size)
    a, _ = forward_train(p, toks, cfg)
    b, _ = forward_train(p, toks, glob)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-6


def test_advance_mask_freezes_rows(rng_key):
    """decode_step(advance=0) must leave pos and future attention unchanged."""
    cfg = tiny("granite-3-2b")
    p = init_params(rng_key, cfg)
    B, S = 2, 9
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, 24, dtype=jnp.float32)
    _, cache, _ = forward_seq(p, toks, cfg, None, cache)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    # step row 0, freeze row 1 (feeding garbage to the frozen row)
    garbage = jnp.array([toks[0, -1], 7], jnp.int32)
    lg1, cache = decode_step(p, garbage, cache, cfg,
                             advance=jnp.array([1, 0], jnp.int32))
    assert int(cache["pos"][0]) == S + 1 and int(cache["pos"][1]) == S
    # resume row 1 with a real token: result must equal a never-frozen run
    cache2 = init_cache(cfg, B, 24, dtype=jnp.float32)
    _, cache2, _ = forward_seq(p, toks, cfg, None, cache2)
    cache2["pos"] = jnp.full((B,), S, jnp.int32)
    real = jnp.array([5, 6], jnp.int32)
    # frozen path: row1 skipped one step then fed `real[1]`
    lg_frozen, _ = decode_step(p, real, cache, cfg,
                               advance=jnp.array([0, 1], jnp.int32))
    lg_clean, _ = decode_step(p, real, cache2, cfg)
    err = float(jnp.max(jnp.abs(lg_frozen[1] - lg_clean[1])))
    assert err < 1e-4, f"frozen-row resume diverged: {err}"


@pytest.mark.parametrize("name", ["mamba2-780m", "zamba2-1.2b"])
def test_mixed_length_prefill_state_exact(name, rng_key):
    """Regression (ISSUE 2): recurrent-state prefill of a padded
    mixed-length batch must equal per-row unpadded prefill — without the
    seq_lens mask, pad tokens beyond a short row's length polluted its
    ssm/conv state (and any later decode from it)."""
    cfg = tiny(name)
    p = init_params(rng_key, cfg)
    B, S = 3, 24
    lens = jnp.array([9, 24, 15], jnp.int32)
    toks = jax.random.randint(rng_key, (B, S), 1, cfg.vocab_size)

    cache = init_cache(cfg, B, 32, dtype=jnp.float32)
    _, cache, _ = forward_seq(p, toks, cfg, None, cache, seq_lens=lens)

    for i in range(B):
        L = int(lens[i])
        solo = init_cache(cfg, 1, 32, dtype=jnp.float32)
        _, solo, _ = forward_seq(p, toks[i:i + 1, :L], cfg, None, solo)
        for nm in ("ssm", "conv"):
            got = cache[nm][:, i]
            want = solo[nm][:, 0]
            err = float(jnp.max(jnp.abs(got - want)))
            assert err < 1e-5, f"row {i} ({nm}): padded-state err {err}"
