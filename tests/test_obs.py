"""End-to-end episode tracing (ISSUE 9): tracer invariants, Perfetto
export structure, critical-path attribution, threaded-vs-simulated trace
parity, and the counter/summary satellites.

1. Components partition: per episode, the tracer's per-stage components
   are the intervals between consecutive lifecycle marks — they sum to
   the submission→commit E2E latency by construction, and the report
   verifies the residual on real runs.
2. Threaded engine traces: every episode of an agentic engine-direct run
   (parks, resumes, multi-turn) yields a well-formed canonical state
   sequence, park/resume flow arrows, and ≤1% component-sum residual.
3. Parity: the virtual-time simulator emits the SAME canonical state
   sequence and the SAME flow-kind chain for an episode with the same
   tool-call count — one span model across both runtimes.
4. Chrome export: process/thread metadata, X slices, s/f flow pairs with
   matching ids, and the synthesized per-episode component slices the
   report reads.
5. Satellites: counters_snapshot() merges RolloutStats into the recorder
   (explicit counters win collisions); summary math survives a
   zero-length run.

Agentic rows emit CALL deterministically (module-scoped sampler bias, the
test_env_stage idiom), so both engines replay identical episodes.
"""
import json
import random
import time

import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_lm
from repro.data import tokenizer as tok
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod
from repro.obs import COMPONENT_OF, TERMINAL_STATES, Tracer
from repro.obs.report import analyze, load_episodes, main as report_main
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest

CALL_AT = (2,)          # sampled-token counter that emits CALL (one park)


@pytest.fixture(scope="module", autouse=True)
def _biased_sampling():
    """Deterministic CALL emission at the CALL_AT counters; EOS remapped
    so rows run their full budget (identical across engines)."""
    mp = pytest.MonkeyPatch()
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = jnp.zeros(counters.shape, bool)
        for c in CALL_AT:
            hit = hit | (counters == c)
        return jnp.where(hit, tok.CALL, s)

    mp.setattr(pf_mod, "_sample_rows", biased)
    mp.setattr(eng_mod, "_sample_rows", biased)
    yield
    mp.undo()


# -- 1. tracer core -------------------------------------------------------

def _scripted_trace(tr: Tracer):
    """One maximally-eventful episode: park/env/resume then train."""
    a = tr.new_trace("tenantA")
    tr.mark(a, "submitted", 0.0)
    tr.mark(a, "queued", 0.5)
    tr.mark(a, "prefill", 1.0)
    tr.mark(a, "decode", 2.0)
    fid = tr.next_flow("park")
    tr.span(("rollout", "slot-0"), "tenantA", 2.0, 3.0, trace=a,
            flow_out=fid)
    tr.mark(a, "parked", 3.0)
    tr.mark(a, "env", 3.25)
    rf = tr.next_flow("resume")
    tr.span(("env", "worker-0"), "tenantA", 3.25, 4.0, trace=a,
            flow_in=fid, flow_out=rf)
    tr.mark(a, "resume_queued", 4.0)
    tr.mark(a, "prefill", 4.25)
    tr.mark(a, "decode", 4.5)
    tr.span(("rollout", "slot-1"), "tenantA", 4.5, 5.0, trace=a,
            flow_in=rf)
    tr.mark(a, "completed", 5.0)
    tr.mark(a, "train", 5.5)
    tr.mark(a, "committed", 6.0)
    return a


def test_components_partition_e2e():
    """Intervals between consecutive marks are charged to the state
    entered first, so components sum EXACTLY to t_last - t_first and
    every non-terminal state has a component label."""
    tr = Tracer()
    a = _scripted_trace(tr)
    info = tr.components()[a]
    assert info["terminal"] == "committed"
    assert info["task"] == "tenantA"
    assert sum(info["components"].values()) == pytest.approx(
        info["t1"] - info["t0"], abs=1e-12)
    # both visits to prefill/decode accumulate into one component each
    assert info["components"]["prefill"] == pytest.approx(1.25)
    assert info["components"]["decode"] == pytest.approx(1.5)
    assert info["components"]["env"] == pytest.approx(0.75)
    assert set(info["components"]) <= set(COMPONENT_OF.values())
    assert tr.state_sequence(a)[0] == "submitted"
    assert tr.state_sequence(a)[-1] in TERMINAL_STATES
    assert tr.flow_kinds_of(a) == ["park", "resume"]


def test_ring_buffer_overflow_counts_drops():
    tr = Tracer(capacity=4)
    a = tr.new_trace("t")
    for i in range(10):
        tr.mark(a, "queued", float(i))
    assert tr.dropped_events == 6
    assert len(tr.marks()[a]) == 4


def test_mark_none_trace_is_noop():
    """Hot-path contract: untraced rows (trace None) cost one compare."""
    tr = Tracer()
    tr.mark(None, "decode", 1.0)
    assert tr.marks() == {}


def test_export_chrome_structure():
    """Perfetto-loadable: process/thread metadata, X slices on real
    tracks, paired s/f flow events, and the synthesized episodes process
    carrying the component slices report.py reads."""
    tr = Tracer()
    _scripted_trace(tr)
    tr.instant(("manager", "queue"), "stale_drop", 5.9)
    doc = tr.export_chrome()
    evs = doc["traceEvents"]
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"rollout", "env", "episodes", "manager"} <= procs
    threads = {e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"slot-0", "slot-1", "worker-0", "tenantA#0"} <= threads
    # every flow start has a matching finish with the same id, and the
    # finish binds to the enclosing slice's start (bp == "e")
    starts = {e["id"] for e in evs if e["ph"] == "s"}
    finishes = {e["id"] for e in evs if e["ph"] == "f"}
    assert starts and starts == finishes
    assert all(e["bp"] == "e" for e in evs if e["ph"] == "f")
    # episode component slices carry the decomposition
    comp = [e for e in evs if e.get("cat") == "episode"]
    assert {e["name"] for e in comp} == {
        "admission_wait", "queue_wait", "prefill", "decode",
        "env_queue_wait", "env", "resume_wait", "completed_wait", "train"}
    assert all(e["args"]["terminal"] == "committed" for e in comp)
    assert any(e["ph"] == "i" for e in evs)
    assert doc["otherData"]["dropped_events"] == 0


def test_report_cli(tmp_path):
    """python -m repro.obs.report over a dumped trace: loads episodes,
    zero residual, names the bottleneck."""
    tr = Tracer()
    _scripted_trace(tr)
    p = tmp_path / "trace.json"
    out_json = tmp_path / "report.json"
    tr.dump_json(str(p))
    assert report_main([str(p), "--json", str(out_json)]) == 0
    rep = json.loads(out_json.read_text())
    assert rep["episodes"] == 1
    assert rep["max_relative_residual"] <= 1e-9
    ten = rep["tenants"]["tenantA"]
    assert ten["bottleneck"] == "decode"
    assert ten["e2e_p50"] == pytest.approx(6.0)


# -- 2./3. engine traces + sim parity ------------------------------------

_CACHE = {}


def _traced_engine_run():
    """Engine-direct agentic run (env stage + disagg prefill) with the
    tracer on; returns (tracer, completions by submit order)."""
    if "threaded" in _CACHE:
        return _CACHE["threaded"]
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg)]
    agentic = make_env("hopsearch", kb_size=8, hops=2, seed=0)
    agentic.env_latency_mean = 0.0
    rng = random.Random(7)
    reqs = []
    for i in range(4):
        prompt, truth = agentic.sample_prompt(rng)
        reqs.append(RolloutRequest("hop", 0, prompt, truth, agentic,
                                   max_new_tokens=6, seed=i, max_turns=2))
    tr = Tracer()
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=96, seed=0, env_stage=True,
                                  env_workers=2, tracer=tr)
    eng.set_adapters(0, trees[0])
    for r in reqs:
        eng.submit(r)
    comps = {}
    deadline = time.monotonic() + 120
    while not eng.idle() and time.monotonic() < deadline:
        progressed = eng.step()
        for c in eng.drain_completions():
            comps[c.submit_index] = c
        if not progressed:
            time.sleep(0.0005)
    assert len(comps) == len(reqs)
    eng._env.halt()
    _CACHE["threaded"] = (tr, comps)
    return tr, comps


def _canon(seq):
    """Collapse a state sequence to its canonical shape: drop the
    runtime-only 'submitted'/'ready' states (engine-direct runs have no
    admission stage; 'ready' only appears under disaggregated prefill)
    and the trainer tail — what remains is the episode's stage walk."""
    keep = [s for s in seq if s not in ("submitted", "ready", "train",
                                       "committed", "completed_wait")]
    return keep


def test_threaded_engine_traces_every_episode():
    """Every episode: starts queued, ends completed, interleaves
    parked→env→resume_queued→prefill→decode per tool turn, carries
    matching park/resume flow arrows, and its components sum to the E2E
    latency within 1%."""
    tr, comps = _traced_engine_run()
    infos = tr.components()
    assert len(infos) == len(comps)
    for trace, info in infos.items():
        seq = tr.state_sequence(trace)
        assert seq[0] == "queued"
        assert seq[-1] == "completed"
        assert "decode" in seq
        n_parks = seq.count("parked")
        assert n_parks >= 1          # biased sampler forces >= 1 CALL
        # each park is followed by env -> resume_queued, then the row
        # re-enters via prefill (replay) before decoding again
        for i, s in enumerate(seq):
            if s == "parked":
                assert seq[i + 1] == "env"
                assert seq[i + 2] == "resume_queued"
        assert tr.flow_kinds_of(trace) == ["park", "resume"] * n_parks
        e2e = info["t1"] - info["t0"]
        assert e2e > 0
        assert sum(info["components"].values()) == pytest.approx(
            e2e, rel=0.01)
        assert info["components"]["env"] > 0.0


def test_trace_parity_threaded_vs_sim():
    """The simulator's traces have the SAME span structure as the
    threaded engine's: identical canonical per-episode state sequences
    and identical flow-kind chains for an episode with the same number of
    tool calls — stage-for-stage, arrow-for-arrow."""
    from repro.configs import get_config
    from repro.core.manager import TaskSpec
    from repro.core.simulator import (HardwareModel, Simulator,
                                      WorkloadModel)
    tr, comps = _traced_engine_run()
    # pick one threaded episode and count its tool turns
    trace = min(tr.components())
    thr_seq = _canon(tr.state_sequence(trace))
    n_calls = thr_seq.count("parked")
    sim = Simulator(get_config("qwen3-0.6b"), HardwareModel(), trace=True)
    wl = WorkloadModel(prompt_len=64, gen_len=128, rows=4,
                       n_tool_calls=n_calls, env_latency_mean=3.0)
    done = []
    sim.submit_rollout(TaskSpec("hop", "search"), wl, 0,
                       on_done=lambda: done.append(1))
    sim.run()
    assert done
    sim_trace = min(sim.tracer.components())
    sim_seq = _canon(sim.tracer.state_sequence(sim_trace))
    assert sim_seq == thr_seq
    assert (sim.tracer.flow_kinds_of(sim_trace)
            == tr.flow_kinds_of(trace))
    # and the sim's components partition its virtual E2E exactly
    info = sim.tracer.components()[sim_trace]
    assert sum(info["components"].values()) == pytest.approx(
        info["t1"] - info["t0"])


def test_threaded_chrome_export_loads_in_report():
    """The real engine run's export round-trips through the report:
    every episode reconstructed, residual within 1%, bottleneck named."""
    tr, _ = _traced_engine_run()
    doc = tr.export_chrome()
    res = analyze(load_episodes(doc))
    assert res["episodes"] == len(tr.components())
    assert res["max_relative_residual"] <= 0.01
    assert res["tenants"]["hop"]["bottleneck"]


# -- 5. satellites --------------------------------------------------------

def test_counters_snapshot_merges_rollout_stats():
    """ONE source of truth: RolloutStats int fields surface in
    counters_snapshot()/summarize() without mirroring incr calls;
    explicit counters win name collisions; bools/floats/zeros excluded."""
    from repro.core.manager import MultiTaskManager
    from repro.core.metrics import MetricsRecorder, summarize
    from repro.rollout.engine import RolloutStats
    rec = MetricsRecorder({"rollout": 1})
    stats = RolloutStats()
    stats.parks = 3
    stats.preemptions = 7          # rows — collides with the event counter
    stats.decode_seconds = 4.2     # float: never a counter
    rec.attach_rollout_stats(stats)
    rec.incr("preemptions")        # 1 preemption EVENT
    snap = rec.counters_snapshot()
    assert snap["parks"] == 3
    assert snap["preemptions"] == 1          # explicit counter wins
    assert "decode_seconds" not in snap
    assert "completions" not in snap         # zero fields omitted
    stats.parks = 5                          # live view, not a copy
    assert rec.counters_snapshot()["parks"] == 5
    out = summarize(MultiTaskManager(), rec)
    assert out["n_parks"] == 5.0
    assert out["n_preemptions"] == 1.0


def test_summarize_zero_length_run():
    """Degenerate run regression (satellite): a recorder that never saw
    an interval or sample must summarize to zeros, not raise."""
    from repro.core.manager import MultiTaskManager
    from repro.core.metrics import MetricsRecorder, summarize
    rec = MetricsRecorder({"rollout": 2, "train": 1})
    assert rec.utilization_pct() == 0.0
    assert rec.idle_pct() == 0.0
    assert rec.slot_utilization_pct() == 0.0
    assert rec._depth_stats([], ("a", "b")) == {}
    assert rec.counters_snapshot() == {}
    out = summarize(MultiTaskManager(), rec)
    assert out["span_s"] == 0.0
    assert out["utilization_pct"] == 0.0
    assert out["idle_pct"] == 0.0
    assert out["steps_per_hr"] == 0.0
    assert out["slot_util_pct"] == 0.0
