"""MARLaaS core invariants: manager on-policy versioning, FIFO buffer,
admission control, metrics."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.admission import (AdmissionConfig, AdmissionController,
                                  task_state_bytes)
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.core.metrics import MetricsRecorder
from repro.rl.types import TrajectoryBatch


def _tb(tid, v):
    z = np.zeros((2, 4), np.float32)
    return TrajectoryBatch(task_id=tid, version=v,
                           tokens=z.astype(np.int32),
                           prompt_lens=np.ones(2, np.int32),
                           total_lens=np.full(2, 3, np.int32),
                           rewards=np.zeros(2, np.float32), group_size=2)


def test_next_policy_issued_once_per_version():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k", target_steps=2))
    m.admit("t")
    assert m.next_policy("t") == (0, None)
    assert m.next_policy("t") is None          # v0 already issued
    m.enqueue(_tb("t", 0))
    b = m.pop_batch()
    m.commit("t", None, None, b.version)
    assert m.next_policy("t") == (1, None)     # unlocked by the commit


def test_stale_trajectory_dropped_and_counted():
    # the on-policy assert became a bounded-staleness admission check:
    # at the default max_staleness=0 a stale batch is DROPPED and counted
    # (drop-or-train decision), never trained — and never an exception
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k"))
    m.admit("t")
    m.next_policy("t")
    m.enqueue(_tb("t", 0))
    m.pop_batch()
    m.commit("t", None, None, 0)
    assert m.enqueue(_tb("t", 0)) is False     # v0 after commit of v1 = stale
    assert m.pop_batch() is None               # dropped, not queued
    drops = m.drop_counters()
    assert drops["stale_batches_dropped"] == 1
    assert drops["stale_rows_dropped"] == 2


def test_stale_batch_within_window_admitted():
    m = MultiTaskManager(max_staleness=1, async_mode=True)
    m.submit(TaskSpec("t", "gsm8k"))
    m.admit("t")
    m.next_policy("t")
    m.enqueue(_tb("t", 0))
    m.commit("t", None, None, 0)
    assert m.enqueue(_tb("t", 0)) is True      # lag 1 <= max_staleness
    b = m.pop_batch()
    m.commit("t", None, None, b.version)       # lag-1 commit admitted too
    assert m.tasks["t"].version == 2


def test_commit_wrong_version_rejected():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k"))
    m.admit("t")
    with pytest.raises(AssertionError):
        m.commit("t", None, None, 3)


def test_buffer_fifo_across_tasks():
    m = MultiTaskManager()
    for tid in ("a", "b", "c"):
        m.submit(TaskSpec(tid, "gsm8k"))
        m.admit(tid)
        m.next_policy(tid)
    m.enqueue(_tb("b", 0))
    m.enqueue(_tb("a", 0))
    m.enqueue(_tb("c", 0))
    order = [m.pop_batch().task_id for _ in range(3)]
    assert order == ["b", "a", "c"]


def test_task_finishes_at_target_steps():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k", target_steps=2))
    m.admit("t")
    for v in range(2):
        m.next_policy("t")
        m.enqueue(_tb("t", v))
        m.commit("t", None, None, v)
    assert m.tasks["t"].status == "finished"
    assert m.next_policy("t") is None
    assert m.all_done()


def test_admission_budget():
    cfg = get_config("granite-3-2b")
    spec = TaskSpec("t0", "gsm8k", group_size=4, num_groups=2,
                    max_new_tokens=64)
    need = task_state_bytes(cfg, spec, prompt_len=64)
    assert need > 0
    ac = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=2.5 * need, strict=True))
    assert ac.try_admit(TaskSpec("a", "gsm8k", group_size=4, num_groups=2,
                                 max_new_tokens=64))
    assert ac.try_admit(TaskSpec("b", "gsm8k", group_size=4, num_groups=2,
                                 max_new_tokens=64))
    assert not ac.try_admit(TaskSpec("c", "gsm8k", group_size=4, num_groups=2,
                                     max_new_tokens=64))
    ac.release("a")
    assert ac.try_admit(TaskSpec("c", "gsm8k", group_size=4, num_groups=2,
                                 max_new_tokens=64))
    assert ac.used_bytes <= 2.5 * need


def test_admission_ssm_is_length_independent():
    cfg = get_config("mamba2-780m")
    short = TaskSpec("s", "gsm8k", max_new_tokens=8)
    long = TaskSpec("l", "gsm8k", max_new_tokens=2048)
    assert (task_state_bytes(cfg, long, 64) - task_state_bytes(cfg, short, 64)
            == 0)  # pure-SSM state does not grow with generation length
    att = get_config("granite-3-2b")
    assert task_state_bytes(att, long, 64) > task_state_bytes(att, short, 64)


def test_metrics_util_and_idle():
    rec = MetricsRecorder({"rollout": 4, "train": 1})
    rec.record("rollout", "decode", "t", 0.0, 10.0, 4)
    rec.record("train", "train", "t", 10.0, 20.0, 1)
    assert rec.span() == 20.0
    assert 0 < rec.utilization_pct() < 100
    idle = rec.idle_pct()
    # rollout busy half the span (40 dev-s of 80), train busy 10 of 100 total
    assert abs(idle - 100 * (1 - 50.0 / 100.0)) < 1e-6
