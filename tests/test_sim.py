"""Simulator + scheduling-policy behaviour (paper's qualitative claims must
hold in the model: MARLaaS dominates, sync has barrier penalty, util/idle
ordering, TTFS ordering, admission throttles concurrency)."""
import pytest

from repro.configs import get_config
from repro.core.admission import AdmissionConfig
from repro.core.manager import TaskSpec
from repro.core.metrics import summarize
from repro.core.policies import POLICIES, run_sim
from repro.core.simulator import HardwareModel, PAPER_WORKLOADS


def _run(policy, n_tasks=6, steps=5, env="search", budget=200e9):
    cfg = get_config("qwen3-0.6b")
    hw = HardwareModel(n_devices=16, train_devices=2)
    specs = [TaskSpec(f"{env}-{i}", env, target_steps=steps)
             for i in range(n_tasks)]
    wls = {s.task_id: PAPER_WORKLOADS[env] for s in specs}
    mgr, rec = run_sim(policy, cfg, hw, specs, wls,
                       AdmissionConfig(memory_budget_bytes=budget))
    return summarize(mgr, rec)


def test_all_policies_complete_all_steps():
    for pol in POLICIES:
        s = _run(pol, n_tasks=3, steps=3)
        assert s["total_steps"] == 9, pol


def test_marlaas_dominates_throughput():
    res = {pol: _run(pol) for pol in POLICIES}
    assert res["marlaas"]["steps_per_hr"] > res["multilora_sync"]["steps_per_hr"]
    assert res["marlaas"]["steps_per_hr"] > res["single_colloc"]["steps_per_hr"]
    assert res["marlaas"]["steps_per_hr"] > res["single_disagg"]["steps_per_hr"]


def test_marlaas_highest_utilization_lowest_idle():
    res = {pol: _run(pol) for pol in POLICIES}
    assert res["marlaas"]["utilization_pct"] == max(
        r["utilization_pct"] for r in res.values())
    assert res["marlaas"]["idle_pct"] <= res["single_disagg"]["idle_pct"]


def test_ttfs_sequential_worst():
    res = {pol: _run(pol) for pol in POLICIES}
    assert res["single_disagg"]["ttfs_mean_s"] > res["marlaas"]["ttfs_mean_s"]
    assert res["multilora_sync"]["ttfs_mean_s"] < res["single_disagg"]["ttfs_mean_s"]


def test_throughput_scales_then_saturates():
    """Fig 6 shape: steps/hr grows with concurrency, sub-linearly at the top."""
    t1 = _run("marlaas", n_tasks=1)["steps_per_hr"]
    t4 = _run("marlaas", n_tasks=4)["steps_per_hr"]
    t16 = _run("marlaas", n_tasks=16)["steps_per_hr"]
    assert t4 > 1.5 * t1
    assert t16 > t4
    assert (t16 / t4) < (t4 / t1) * 2     # diminishing returns


def test_admission_throttles():
    """A tight KV budget serializes admissions (longer TTFS tail)."""
    tight = _run("marlaas", n_tasks=8, budget=2e9)
    loose = _run("marlaas", n_tasks=8, budget=400e9)
    assert tight["ttfs_max_s"] > loose["ttfs_max_s"]
    assert tight["total_steps"] == loose["total_steps"]     # still completes


def test_multi_lora_weight_sharing_matters():
    """Fused multi-LoRA decode (shared weight reads) must beat per-task
    weight streaming — the Table 4 'w/o multi-LoRA' ablation."""
    cfg = get_config("qwen3-0.6b")
    hw = HardwareModel(n_devices=16, train_devices=2)
    from repro.core.simulator import Simulator, _DecodeJob
    sim = Simulator(cfg, hw)
    jobs_fused = [_DecodeJob(f"t{i}", 0, 8, 1e9, [("decode", 100.0)],
                             tokens_left=100.0, multi_lora=True)
                  for i in range(4)]
    for j in jobs_fused:
        sim.decode_set[j.task_id] = j
    fused = sim._step_seconds()
    for j in sim.decode_set.values():
        j.multi_lora = False
    unfused = sim._step_seconds()
    assert unfused > fused * 1.5
