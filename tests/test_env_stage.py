"""Disaggregated environment-interaction stage (ISSUE 4): parity, the
no-slot-held-while-waiting invariant, multi-turn episode protocol, and the
tool-call Future lifecycle bugfixes.

1. With ``env_stage=True`` a row that samples CALL is PARKED (slot vacated
   and refilled) and later resumes through the prefill path — output is
   token-for-token identical to the freeze-in-slot baseline and to
   one-shot generate() across attention / SSM / hybrid, both fill paths,
   including preempt-at-any-turn (hypothesis).
2. No decode slot is ever occupied by a tool-waiting row:
   ``tool_wait_slot_steps == 0`` (asserted per-step inside the engine);
   the frozen baseline books the dead weight.
3. Multi-turn episodes: per-episode stateful ToolSessions, turn budgets
   (finish_reason "turn_limit"), budget-exempt forced tokens across turns.
4. Futures of timed-out/evicted tool calls are cancelled (they no longer
   burn the shared pool), and a late tool response is never force-fed into
   a row that timed out or into the slot's next occupant.

Agentic rows here emit CALL deterministically: the per-row sampler is
biased at fixed token counters (module-scoped patch), which applies
identically to every engine — so whatever episodes arise, all engines
replay the same ones.
"""
import random
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_lm
from repro.data import tokenizer as tok
from repro.envs.base import Env, ToolSession
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
import repro.rollout.engine as eng_mod
import repro.rollout.prefill as pf_mod
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest)
from repro.rollout.env_stage import EnvStage

CALL_AT = (2, 9)          # sampled-token counters that emit CALL
FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}
_CACHE = {}


@pytest.fixture(scope="module", autouse=True)
def _biased_sampling():
    """Deterministic CALL emission: every engine's sampler returns CALL at
    the CALL_AT counters (EOS remapped so rows run their full budget).
    Applied before any kernel in this module traces; undone afterwards."""
    mp = pytest.MonkeyPatch()
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = jnp.zeros(counters.shape, bool)
        for c in CALL_AT:
            hit = hit | (counters == c)
        return jnp.where(hit, tok.CALL, s)

    mp.setattr(pf_mod, "_sample_rows", biased)
    mp.setattr(eng_mod, "_sample_rows", biased)
    yield
    mp.undo()


def _requests(n=6):
    """Mixed multi-turn agentic (hopsearch) + plain rows, explicit seeds."""
    agentic = make_env("hopsearch", kb_size=8, hops=2, seed=0)
    agentic.env_latency_mean = 0.0      # parity tests: timing-free
    plain = make_env("gsm8k")
    rng = random.Random(7)
    reqs = []
    for i in range(n):
        env = agentic if i % 2 == 0 else plain
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=6, seed=i))
    return reqs


def _family(fam: str):
    """(cfg, params, trees, requests, one-shot reference) per family."""
    if fam not in _CACHE:
        cfg = tiny_lm(FAMILIES[fam])
        params = init_params(jax.random.PRNGKey(0), cfg)
        trees = [init_lora(jax.random.PRNGKey(1), cfg),
                 init_lora(jax.random.PRNGKey(2), cfg)]
        reqs = _requests()
        ref_eng = RolloutEngine(cfg, params, max_len=96, seed=0)
        ref, _ = ref_eng.generate(reqs, trees)   # freeze-in-slot oracle
        _CACHE[fam] = (cfg, params, trees, reqs, ref)
    return _CACHE[fam]


_ENGINES = {}


def _engine(fam: str, **kw):
    """Reusable continuous engine per (family, mode) — requests carry
    explicit seeds, so repeated drives produce identical tokens."""
    key = (fam, tuple(sorted(kw.items())))
    if key not in _ENGINES:
        cfg, params, trees, _, _ = _family(fam)
        eng = ContinuousRolloutEngine(cfg, params, max_slots=2,
                                      max_adapters=2, max_len=96, seed=0,
                                      **kw)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        _ENGINES[key] = eng
    return _ENGINES[key]


def _drive(eng, reqs, preempt_step=0, victims=(), max_iters=5000):
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, preempted, iters = {}, 0, 0
    deadline = time.monotonic() + 120
    while not eng.idle() and iters < max_iters:
        progressed = eng.step()
        # only productive steps count against the budget: cold-start jit
        # compiles on the async prefill workers spin thousands of
        # no-progress iterations (the 120s deadline guards real hangs)
        if progressed:
            iters += 1
            if iters == preempt_step:
                for v in victims:
                    preempted += eng.preempt_tenant(v)
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
        if not progressed:
            if time.monotonic() > deadline:
                break
            time.sleep(0.0005)
    assert len(comps) == len(reqs), (
        f"engine failed to drain: {len(comps)}/{len(reqs)}")
    return comps, preempted


def _assert_matches_ref(comps, ref, ctx=""):
    for i, r in enumerate(ref):
        c = comps[i]
        assert list(c.tokens) == r["tokens"], f"{ctx}: token mismatch @{i}"
        assert list(c.gen_loss_mask) == r["gen_loss_mask"], ctx
        np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                   atol=1e-5)


# -- parity ---------------------------------------------------------------
@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_env_stage_matches_frozen_baseline_token_for_token(fam):
    """Parking + prefill-path resume must reproduce the freeze-in-slot
    output bit-for-bit: same forward math, same (key, counter) sampling,
    same forced tool tokens (incl. the forced RESP opener installed at the
    splice, whose logprob comes off the prefill logits)."""
    _, _, _, reqs, ref = _family(fam)
    eng = _engine(fam, env_stage=True, disagg_prefill=True)
    comps, _ = _drive(eng, reqs)
    _assert_matches_ref(comps, ref, f"{fam}/disagg")
    assert eng.stats.parks > 0 and eng.stats.resumes > 0
    assert eng.stats.tool_wait_slot_steps == 0
    # multi-turn episodes actually ran: 2 tool turns per agentic row
    # (force-fed RESP openers carry mask 0; a sampled RESP carries mask 1)
    for i in range(0, len(reqs), 2):
        c = comps[i]
        gen = list(c.tokens)[c.prompt_len:]
        mask = list(c.gen_loss_mask)
        assert sum(1 for j, t in enumerate(gen)
                   if t == tok.RESP and mask[j] == 0.0) == 2


def test_env_stage_parity_fused_fill_path():
    """The resume path also works under the fused refill baseline (the
    forced first token rides the one-call batched refill)."""
    _, _, _, reqs, ref = _family("attention")
    eng = _engine("attention", env_stage=True, disagg_prefill=False)
    comps, _ = _drive(eng, reqs)
    _assert_matches_ref(comps, ref, "attention/fused")
    assert eng.stats.parks > 0 and eng.stats.resumes > 0
    assert eng.stats.tool_wait_slot_steps == 0


def test_frozen_baseline_unchanged_and_books_dead_weight():
    """The retained freeze-in-slot baseline still matches one-shot output;
    with real env latency it books tool_wait_slot_steps > 0 — the slot
    dead weight the env stage eliminates on the same workload."""
    _, _, _, reqs, ref = _family("attention")
    frozen = _engine("attention")
    comps, _ = _drive(frozen, reqs)
    _assert_matches_ref(comps, ref, "frozen")
    # now with latency: frozen slots span decode steps; env-stage does not
    agentic = make_env("hopsearch", kb_size=8, hops=2, seed=0)
    agentic.env_latency_mean, agentic.env_latency_std = 0.05, 0.0
    plain = make_env("gsm8k")
    rng = random.Random(3)
    reqs2 = []
    for i in range(6):
        env = agentic if i % 2 == 0 else plain
        prompt, truth = env.sample_prompt(rng)
        reqs2.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                    max_new_tokens=6, seed=100 + i))
    f2 = _engine("attention", scheduler="fifo")
    comps_f, _ = _drive(f2, reqs2)
    e2 = _engine("attention", env_stage=True, disagg_prefill=True,
                 scheduler="fifo")
    comps_e, _ = _drive(e2, reqs2)
    for i in range(len(reqs2)):
        assert list(comps_f[i].tokens) == list(comps_e[i].tokens)
    assert f2.stats.tool_wait_slot_steps > 0     # frozen slots spun
    assert e2.stats.tool_wait_slot_steps == 0    # parked rows never did
    assert e2.stats.env_wait_by_task.get("t0", 0.0) > 0.0
    assert "t1" not in e2.stats.env_wait_by_task  # plain tenant never waits


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_preempt_at_any_turn_replay_parity(fam):
    """Hypothesis: preempting tenants at ANY engine iteration — before,
    between, and after tool turns, including while rows are parked in the
    env stage — yields bit-identical output (parked rows hold no slot, so
    preemption never touches them; resumed rows replay prompt+prefix with
    their original counters)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    _, _, _, reqs, ref = _family(fam)
    eng = _engine(fam, env_stage=True, disagg_prefill=True)
    observed = {"n": 0}

    @given(preempt_step=st.integers(1, 25),
           victims=st.sampled_from([("t0",), ("t1",), ("t0", "t1")]))
    @settings(max_examples=5, deadline=None)
    def check(preempt_step, victims):
        comps, preempted = _drive(eng, reqs, preempt_step, victims)
        observed["n"] += preempted
        _assert_matches_ref(comps, ref, f"{fam} preempt@{preempt_step}")

    check()
    assert observed["n"] > 0               # preemption+replay exercised
    assert eng.stats.replays > 0
    assert eng.stats.tool_wait_slot_steps == 0


# -- multi-turn episode protocol ------------------------------------------
def test_turn_budget_enforced():
    """A CALL sampled with the turn budget spent ends the episode
    (finish_reason turn_limit) instead of dispatching another tool call —
    request-level max_turns overrides the env default."""
    cfg, params, trees, _, _ = _family("attention")
    agentic = make_env("hopsearch", kb_size=8, hops=2, seed=0)
    agentic.env_latency_mean = 0.0
    rng = random.Random(1)
    prompt, truth = agentic.sample_prompt(rng)
    # CALL_AT = (2, 9): with max_turns=1 the second CALL hits the limit
    reqs = [RolloutRequest("a", 0, prompt, truth, agentic,
                           max_new_tokens=12, seed=0, max_turns=1)]
    eng = _engine("attention", env_stage=True, disagg_prefill=True)
    comps, _ = _drive(eng, reqs)
    c = comps[0]
    assert c.finish_reason == "turn_limit"
    gen = list(c.tokens)[c.prompt_len:]
    mask = list(c.gen_loss_mask)
    assert sum(1 for j, t in enumerate(gen)          # exactly one turn ran
               if t == tok.RESP and mask[j] == 0.0) == 1
    # the terminating CALL is recorded (it was sampled) but not dispatched
    assert list(c.tokens)[-1] == tok.CALL
    # frozen baseline enforces the identical rule
    one = RolloutEngine(cfg, params, max_len=96, seed=0)
    ref, _ = one.generate(reqs, trees)
    assert ref[0]["finish_reason"] == "turn_limit"
    assert list(c.tokens) == ref[0]["tokens"]


def test_forced_tokens_budget_exempt_across_turns():
    """Multi-turn force-feeds are budget-exempt: a row doing 2 tool turns
    still samples its full max_new_tokens budget, with every RESP…ENDRESP
    block carrying loss_mask 0."""
    _, _, _, reqs, _ = _family("attention")
    eng = _engine("attention", env_stage=True, disagg_prefill=True)
    comps, _ = _drive(eng, reqs)
    for i in range(0, len(reqs), 2):              # agentic rows
        c = comps[i]
        assert c.finish_reason == "budget"
        assert c.sampled_tokens == reqs[i].max_new_tokens
        assert c.forced_tokens > 0
        toks = list(c.tokens)[c.prompt_len:]
        mask = list(c.gen_loss_mask)
        # two force-fed RESP…ENDRESP blocks (mask 0), one per turn
        forced_resp = sum(1 for j, t in enumerate(toks)
                          if t == tok.RESP and mask[j] == 0.0)
        forced_end = sum(1 for j, t in enumerate(toks)
                         if t == tok.ENDRESP and mask[j] == 0.0)
        assert forced_resp == forced_end == 2
        assert sum(1 for m in mask if m == 1.0) == reqs[i].max_new_tokens


def test_stateful_sessions_survive_preemption():
    """The REPL accumulator session lives on the row, not the slot: a
    preempted-and-replayed episode keeps its register (responses already in
    the prefix are never re-executed), so multi-turn results match the
    uninterrupted oracle."""
    cfg, params, trees, _, _ = _family("attention")
    env = make_env("calcrepl", n_terms=2)
    env.env_latency_mean = 0.0
    rng = random.Random(5)
    reqs = []
    for i in range(4):
        prompt, truth = env.sample_prompt(rng)
        # budget 8: both CALL_AT counters fire before the budget trips
        reqs.append(RolloutRequest("c", 0, prompt, truth, env,
                                   max_new_tokens=8, seed=50 + i))
    one = RolloutEngine(cfg, params, max_len=96, seed=0)
    ref, _ = one.generate(reqs, trees)
    eng = _engine("attention", env_stage=True, disagg_prefill=True)
    comps, preempted = _drive(eng, reqs, preempt_step=4, victims=("c",))
    for i, r in enumerate(ref):
        assert list(comps[i].tokens) == r["tokens"]
    # both tool turns ran (two force-fed RESP openers; a RESP sampled by
    # the toy model carries mask 1 and doesn't count)
    for i in range(4):
        c = comps[i]
        gen = list(c.tokens)[c.prompt_len:]
        mask = list(c.gen_loss_mask)
        forced_resp = [j for j, t in enumerate(gen)
                       if t == tok.RESP and mask[j] == 0.0]
        assert len(forced_resp) == 2


# -- env-stage scheduling machinery ---------------------------------------
def test_env_worker_pool_per_tenant_inflight_cap():
    """EnvWorker pool fairness: with max_inflight_per_tenant=1, one
    tenant's queued calls execute serially while another tenant's call
    proceeds in parallel (a slow-tool tenant cannot monopolize the pool)."""
    peak = {"a": 0, "b": 0}
    lock = threading.Lock()
    cur = {"a": 0, "b": 0}

    class SlowEnv(Env):
        is_agentic = True

        def sample_prompt(self, rng):
            return [tok.BOS], "x"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return [10]

    class CountingSession(ToolSession):
        def __init__(self, env, truth, tid):
            super().__init__(env, truth)
            self.tid = tid

        def call(self, query_ids):
            with lock:
                cur[self.tid] += 1
                peak[self.tid] = max(peak[self.tid], cur[self.tid])
            time.sleep(0.05)
            with lock:
                cur[self.tid] -= 1
            return [10]

    class FakeRow:
        def __init__(self, tid):
            self.session = CountingSession(SlowEnv(), "x", tid)

    stage = EnvStage(n_workers=3, max_inflight_per_tenant=1)
    try:
        jobs = [stage.submit(FakeRow("a"), [1], "a", 0.0) for _ in range(3)]
        jobs.append(stage.submit(FakeRow("b"), [1], "b", 0.0))
        deadline = time.monotonic() + 10
        done = []
        while len(done) < 4 and time.monotonic() < deadline:
            done += stage.drain_resolved()
            time.sleep(0.005)
        assert len(done) == 4
        assert peak["a"] == 1          # tenant a: serialized by the cap
        assert peak["b"] == 1
        assert stage.count() == 0
    finally:
        stage.halt()


def test_halt_cancels_queued_backlog():
    """halt() must cancel the queued backlog instead of letting workers
    drain it (latency sleeps included) for discarded results — otherwise
    runtime shutdown blocks for the queue's worth of env latency."""
    class SlowSession:
        def call(self, query_ids):
            return [10]

    class FakeRow:
        session = SlowSession()

    stage = EnvStage(n_workers=1)
    jobs = [stage.submit(FakeRow(), [1], "a", 0.5) for _ in range(10)]
    t0 = time.monotonic()
    stage.halt()
    assert time.monotonic() - t0 < 2.0, "halt drained the backlog"
    # everything queued was cancelled, not executed
    assert sum(1 for j in jobs if j.cancelled) >= 8
    assert stage.depths() == (0, 0)


def test_resume_jobs_pop_before_fresh_rows():
    """Scheduler resume tier: a re-queued resume job (forced_q pre-loaded)
    pops before a fresh row of the same priority."""
    from repro.rollout.scheduler import SlotScheduler

    class Req:
        task_id, priority, max_new_tokens = "t", 0, 8

    class Row:
        def __init__(self, idx, forced):
            self.req = Req()
            self.sampled = 0
            self.submit_index = idx
            self.forced_q = [tok.RESP] if forced else []

    s = SlotScheduler(policy="srpt")
    fresh, resume = Row(0, False), Row(1, True)
    s.push(fresh, 0)
    s.push(resume, 0)
    assert s.pop(0) is resume
    assert s.pop(0) is fresh


# -- tool-call Future lifecycle (satellite bugfixes) ----------------------
def test_timed_out_tool_futures_are_cancelled():
    """Regression (satellite): a timed-out tool call's Future must be
    cancel()ed at eviction — abandoned env work left queued would keep
    burning the shared pool and starve other tenants' tool calls."""
    from concurrent.futures import ThreadPoolExecutor
    cfg, params, trees, _, _ = _family("attention")
    calls = {"n": 0}

    class CountingEnv(Env):
        is_agentic = True
        env_latency_mean = 0.5          # the latency sleep blocks the pool
        env_latency_std = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("q?"), "42"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            calls["n"] += 1
            return tok.encode("42")

    env = CountingEnv()
    pool = ThreadPoolExecutor(max_workers=1)    # one shared env worker
    eng = ContinuousRolloutEngine(cfg, params, max_slots=3, max_adapters=1,
                                  max_len=96, seed=0, tool_executor=pool,
                                  tool_timeout_s=0.08)
    eng.set_adapters(0, trees[0])
    reqs = [RolloutRequest("x", 0, [tok.BOS] + tok.encode("q?"), "42", env,
                           max_new_tokens=6, seed=i) for i in range(3)]
    pos = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps = {}
    deadline = time.monotonic() + 30
    while len(comps) < 3 and time.monotonic() < deadline:
        eng.step()
        for c in eng.drain_completions():
            comps[pos[c.submit_index]] = c
        time.sleep(0.001)
    assert len(comps) == 3
    assert all(c.finish_reason == "tool_timeout" for c in comps.values())
    # the two queued futures were cancelled before their run_tool started:
    # only the first (already running) call can ever execute
    pool.shutdown(wait=True)
    assert calls["n"] <= 1, "cancelled tool futures still ran"
    eng.shutdown()


def test_late_response_never_reaches_next_occupant():
    """Regression (satellite): after a tool-waiting row times out and its
    slot is refilled, the late-arriving response must never be force-fed
    into the next occupant (frozen baseline `_pending` lifecycle)."""
    cfg, params, trees, _, _ = _family("attention")

    class SlowEnv(Env):
        is_agentic = True
        env_latency_mean = 0.3
        env_latency_std = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("s?"), "7"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return tok.encode("7777")

    env = SlowEnv()
    plain = make_env("gsm8k")
    rng = random.Random(2)
    p_prompt, p_truth = plain.sample_prompt(rng)
    slow_req = RolloutRequest("slow", 0, [tok.BOS] + tok.encode("s?"), "7",
                              env, max_new_tokens=6, seed=0)
    plain_req = RolloutRequest("fast", 0, p_prompt, p_truth, plain,
                               max_new_tokens=24, seed=1)
    # reference: the plain row alone (its stream must be unaffected)
    one = RolloutEngine(cfg, params, max_len=96, seed=0)
    ref, _ = one.generate([plain_req], trees)
    eng = ContinuousRolloutEngine(cfg, params, max_slots=1, max_adapters=1,
                                  max_len=96, seed=0, tool_timeout_s=0.06)
    eng.set_adapters(0, trees[0])
    pos = {eng.submit(slow_req): 0, eng.submit(plain_req): 1}
    comps = {}
    deadline = time.monotonic() + 30
    while len(comps) < 2 and time.monotonic() < deadline:
        eng.step()
        for c in eng.drain_completions():
            comps[pos[c.submit_index]] = c
        time.sleep(0.001)
    assert comps[0].finish_reason == "tool_timeout"
    assert not eng._pending                     # no orphaned future refs
    # wait past the tool latency, keep stepping: nothing may arrive
    time.sleep(0.35)
    eng.step()
    fast = comps[1]
    assert all(m == 1.0 for m in fast.gen_loss_mask)   # nothing force-fed
    assert list(fast.tokens) == ref[0]["tokens"], \
        "late tool response leaked into the slot's next occupant"
    eng.shutdown()


def test_env_stage_timeout_discards_late_response():
    """Env-stage flavour of the late-response hazard: a parked row that
    times out completes with tool_timeout; the worker's late result is
    discarded by the cancelled flag (never becomes a resume job)."""
    cfg, params, trees, _, _ = _family("attention")

    class SlowEnv(Env):
        is_agentic = True
        env_latency_mean = 0.4
        env_latency_std = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("s?"), "7"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return tok.encode("7777")

    env = SlowEnv()
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=96, seed=0, tool_timeout_s=0.05,
                                  env_stage=True, env_workers=1)
    eng.set_adapters(0, trees[0])
    req = RolloutRequest("slow", 0, [tok.BOS] + tok.encode("s?"), "7", env,
                         max_new_tokens=6, seed=0)
    pos = {eng.submit(req): 0}
    comps = {}
    deadline = time.monotonic() + 30
    while len(comps) < 1 and time.monotonic() < deadline:
        eng.step()
        for c in eng.drain_completions():
            comps[pos[c.submit_index]] = c
        time.sleep(0.001)
    assert comps[0].finish_reason == "tool_timeout"
    assert comps[0].slot == -1          # it held NO slot while waiting
    time.sleep(0.45)                    # let the worker's late call land
    eng.step()
    assert eng.stats.resumes == 0       # discarded, not resumed
    assert eng.idle()
    eng.shutdown()


def test_generate_cancels_pending_futures_at_deadline():
    """The round-fused engine cancels pending tool futures when its wall
    deadline aborts the round (same starvation bugfix, legacy path)."""
    cfg, params, trees, _, _ = _family("attention")
    calls = {"n": 0}

    class NeverEnv(Env):
        is_agentic = True
        env_latency_mean = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("q?"), "1"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            calls["n"] += 1
            return [10]

    env = NeverEnv()
    from concurrent.futures import ThreadPoolExecutor
    pool = ThreadPoolExecutor(max_workers=1)
    eng = RolloutEngine(cfg, params, max_len=96, seed=0)
    # warm the kernels first so the deadline below measures scheduling,
    # not compile time
    plain = make_env("gsm8k")
    rng = random.Random(0)
    warm = []
    for i in range(2):
        p, t = plain.sample_prompt(rng)
        warm.append(RolloutRequest("w", 0, p, t, plain, max_new_tokens=3,
                                   seed=900 + i))
    eng.generate(warm, trees)
    # block the single pool worker: BOTH rows' tool calls stay queued and
    # must be cancelled when the deadline aborts the round
    blocker = pool.submit(time.sleep, 2.0)
    reqs = [RolloutRequest("n", 0, [tok.BOS] + tok.encode("q?"), "1", env,
                           max_new_tokens=4, seed=i) for i in range(2)]
    res, _ = eng.generate(reqs, trees, tool_executor=pool, deadline_s=1.0)
    assert all(r["finish_reason"] == "tool_timeout" for r in res)
    pool.shutdown(wait=True)
    blocker.result()
    assert calls["n"] == 0, "cancelled tool futures still ran"


def test_timeout_then_drain_yields_exactly_one_completion():
    """A parked row whose executing tool call times out completes ONCE with
    tool_timeout; the cancelled job must neither keep the engine non-idle
    for the tool's remaining latency nor produce a second (aborted)
    completion when drain() sweeps the stage."""
    cfg, params, trees, _, _ = _family("attention")

    class StuckEnv(Env):
        is_agentic = True
        env_latency_mean = 1.5         # far beyond the timeout
        env_latency_std = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("s?"), "7"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return tok.encode("7")

    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=96, seed=0, tool_timeout_s=0.05,
                                  env_stage=True, env_workers=1)
    eng.set_adapters(0, trees[0])
    # warm the jit kernels with a plain row so the wall bound below
    # measures stage scheduling, not compile time
    plain = make_env("gsm8k")
    p, t = plain.sample_prompt(random.Random(0))
    eng.submit(RolloutRequest("w", 0, p, t, plain, max_new_tokens=3,
                              seed=77))
    assert len(eng.drain(60.0)) == 1
    eng.stats.completions = 0
    eng.submit(RolloutRequest("st", 0, [tok.BOS] + tok.encode("s?"), "7",
                              StuckEnv(), max_new_tokens=6, seed=0))
    t0 = time.monotonic()
    comps = eng.drain(deadline_s=30.0)
    wall = time.monotonic() - t0
    assert len(comps) == 1                       # exactly one completion
    assert comps[0].finish_reason == "tool_timeout"
    assert eng.stats.completions == 1
    # idle the moment the row timed out — NOT after the 1.5s tool latency
    assert wall < 1.0, f"drain spun on a cancelled executing job ({wall:.2f}s)"
    assert eng.idle()
    eng.shutdown()


def test_tool_error_does_not_strand_sibling_responses():
    """A ToolSession that raises surfaces its error on the engine thread
    (like fut.result() in the baseline) — but only AFTER the rest of the
    resolved batch is processed: the errored row completes with
    finish_reason tool_error and sibling responses still resume."""
    cfg, params, trees, _, _ = _family("attention")

    class FlakyEnv(Env):
        is_agentic = True
        env_latency_mean = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("f?"), "1"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            raise RuntimeError("tool exploded")

    good = make_env("hopsearch", kb_size=8, hops=1, seed=0)
    good.env_latency_mean = 0.0
    rng = random.Random(4)
    g_prompt, g_truth = good.sample_prompt(rng)
    reqs = [RolloutRequest("bad", 0, [tok.BOS] + tok.encode("f?"), "1",
                           FlakyEnv(), max_new_tokens=6, seed=0),
            RolloutRequest("ok", 0, g_prompt, g_truth, good,
                           max_new_tokens=6, seed=1)]
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=96, seed=0, env_stage=True,
                                  env_workers=2)
    eng.set_adapters(0, trees[0])
    pos = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, raised = {}, 0
    deadline = time.monotonic() + 60
    while len(comps) < 2 and time.monotonic() < deadline:
        try:
            eng.step()
        except RuntimeError as e:
            assert "tool exploded" in str(e)
            raised += 1
        for c in eng.drain_completions():
            comps[pos[c.submit_index]] = c
        time.sleep(0.001)
    assert raised >= 1                      # the error did surface
    assert comps[0].finish_reason == "tool_error"
    # sibling episode unharmed: resumed, force-fed, finished naturally
    assert comps[1].finish_reason in ("budget", "turn_limit", "eos")
    gen = list(comps[1].tokens)[comps[1].prompt_len:]
    mask = list(comps[1].gen_loss_mask)
    assert any(t == tok.RESP and mask[j] == 0.0 for j, t in enumerate(gen))
    assert eng.idle()                       # nothing stranded in the stage
    eng.shutdown()


# -- accounting -----------------------------------------------------------
def test_engine_pipeline_accounting_with_env_stage():
    """queued()/idle()/active_tenants()/queued_progress() see parked rows:
    the LRU adapter residency must keep a tenant pinned while its rows sit
    in the env stage."""
    cfg, params, trees, _, _ = _family("attention")

    class SlowEnv(Env):
        is_agentic = True
        env_latency_mean = 0.2
        env_latency_std = 0.0

        def sample_prompt(self, rng):
            return [tok.BOS] + tok.encode("s?"), "7"

        def verify(self, truth, completion_ids):
            return 0.0

        def tool_call(self, query_ids, truth=None):
            return tok.encode("7")

    env = SlowEnv()
    eng = ContinuousRolloutEngine(cfg, params, max_slots=1, max_adapters=1,
                                  max_len=96, seed=0, env_stage=True,
                                  env_workers=1)
    eng.set_adapters(0, trees[0])
    req = RolloutRequest("sl", 0, [tok.BOS] + tok.encode("s?"), "7", env,
                         max_new_tokens=6, seed=0)
    idx = eng.submit(req)
    # step until the row parks (CALL at counter 2)
    deadline = time.monotonic() + 30
    while eng.stats.parks == 0 and time.monotonic() < deadline:
        eng.step()
    assert eng.stats.parks == 1
    assert not eng.idle()
    assert eng.queued() == 1                    # the parked row
    assert "sl" in eng.active_tenants()
    rows, sampled = eng.queued_progress("sl")
    assert rows == 1 and sampled > 0
    q, ex = eng.env_depths()
    assert q + ex == 1
    comps = eng.drain(30.0)
    assert len(comps) == 1 and comps[0].submit_index == idx
    assert eng.active_tenants() == frozenset()
    assert eng.env_depths() == (0, 0)
    eng.shutdown()


def test_metrics_env_intervals_and_summary():
    """Per-task env intervals land in the recorder and summarize() surfaces
    env busy/wait alongside prefill/decode/splice (satellite: the global
    RolloutStats aggregate hid per-tenant tool latency)."""
    from repro.core.manager import MultiTaskManager
    from repro.core.metrics import MetricsRecorder, summarize
    rec = MetricsRecorder({"rollout": 1})
    rec.record("rollout", "decode", "a", 0.0, 1.0)
    rec.record("rollout", "env", "a", 0.0, 0.4)
    rec.record("rollout", "env", "a", 0.2, 0.6)     # overlaps the first
    rec.record("rollout", "env", "b", 1.0, 1.5)
    assert rec.env_wait_seconds() == pytest.approx(1.3)
    assert rec.env_wait_by_task() == pytest.approx({"a": 0.8, "b": 0.5})
    assert rec.env_busy_seconds() == pytest.approx(1.1)  # merged union
    # env time is NOT device-busy time
    assert rec.busy_device_seconds(pool="rollout") == pytest.approx(1.0)
    rec.record_env_sample(0.0, 2, 1)
    rec.record_env_sample(1.0, 0, 0)
    out = summarize(MultiTaskManager(), rec)
    assert out["env_wait_s"] == pytest.approx(1.3)
    assert out["env_busy_s"] == pytest.approx(1.1)
    assert out["env_q_mean"] == pytest.approx(2.0)
    assert out["env_exec_max"] == 1.0
    assert out["decode_busy_s"] == pytest.approx(1.0)


@pytest.mark.slow
def test_runtime_env_stage_end_to_end():
    """MARLaaSRuntime with all three stages disaggregated: agentic +
    plain tenants train to completion; env intervals and env queue depths
    land in the recorder; no slot ever froze on a tool."""
    from repro.core.manager import TaskSpec
    from repro.core.metrics import summarize
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=64, seed=3,
                                      max_slots=4, disagg_prefill=True,
                                      prefill_workers=1, env_stage=True,
                                      env_workers=2, max_turns=2))
    rt.submit_task(TaskSpec("hop", "hopsearch", group_size=2, num_groups=1,
                            max_new_tokens=6, target_steps=2))
    rt.submit_task(TaskSpec("gsm", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=4, target_steps=2))
    rt.run(timeout_s=300.0)
    assert all(st.done for st in rt.mgr.tasks.values())
    assert rt.cengine.stats.parks > 0
    assert rt.cengine.stats.tool_wait_slot_steps == 0
    out = summarize(rt.mgr, rt.rec)
    assert out["env_wait_s"] > 0.0
    assert rt.rec.env_wait_by_task().get("hop", 0.0) > 0.0
    assert "gsm" not in rt.rec.env_wait_by_task()
    assert rt.rec.env_samples                   # depth timeline sampled
