"""Fault-tolerant stage supervision under deterministic chaos (ISSUE 10).

Unit-level: the seeded per-site chaos streams (replayable fault scripts,
per-site caps), the tenant circuit breaker's full state machine on an
injectable clock, checkpoint publish safety (replace-aside, retention,
LATEST scan fallback, torn-publish recovery, async episode round-trip),
env-stage tool-call retry semantics (transient backoff, budgets,
permanent errors, injected faults, worker-death recovery), the RA106
swallowed-exception checker, and the fault sections of the metrics
recorder / trace report.

Runtime-level (slow): the chaos matrix on the real engine — stage-worker
kills recovered by the supervisor, transient tool errors retried to a
bit-identical token stream, permanent tool errors tripping quarantine
through recovery or abandonment — each asserting the extended row
conservation invariant EXACTLY:

    completed == trained + stale_dropped + discarded_tails
                 + failed + quarantine_dropped + orphaned
"""
import os
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.chaos import ChaosConfig, ChaosError, ChaosInjector
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.core.supervisor import (ABANDONED, CLOSED, HALF_OPEN, OPEN,
                                   TenantBreaker)
from repro.envs.base import PermanentToolError, TransientToolError


def _assert_accounting(rt):
    """The extended PR-7 conservation invariant, exactly."""
    acc = rt.row_accounting()
    assert acc["completed"] == (
        acc["trained"] + acc["stale_dropped"] + acc["discarded_tails"]
        + acc["failed"] + acc["quarantine_dropped"] + acc["orphaned"]), acc


# -- chaos injector -------------------------------------------------------

def test_chaos_streams_are_deterministic_and_per_site():
    cfg = ChaosConfig(seed=7, env_worker_kill=0.5, tool_error_transient=0.5)
    a, b = ChaosInjector(cfg), ChaosInjector(cfg)
    for site in ("env_worker_kill", "tool_error_transient"):
        assert [a.fire(site) for _ in range(64)] \
            == [b.fire(site) for _ in range(64)]
    # interleaving one site's draws must not perturb the other's stream
    c = ChaosInjector(cfg)
    mixed = []
    for _ in range(64):
        c.fire("tool_error_transient")
        mixed.append(c.fire("env_worker_kill"))
    d = ChaosInjector(cfg)
    assert mixed == [d.fire("env_worker_kill") for _ in range(64)]
    assert a.counts() == b.counts() and sum(a.counts().values()) > 0


def test_chaos_rate_edges_and_cap():
    inj = ChaosInjector(ChaosConfig(seed=0, prefill_worker_kill=1.0,
                                    max_faults_per_site=3))
    assert [inj.fire("prefill_worker_kill") for _ in range(10)] \
        == [True] * 3 + [False] * 7          # cap is exact
    assert inj.counts() == {"prefill_worker_kill": 3}
    assert not inj.fire("snapshot_drop")     # rate 0.0 never fires
    with pytest.raises(ValueError):
        inj.fire("not_a_site")


def test_chaos_config_enabled_gate():
    assert not ChaosConfig().enabled
    assert ChaosConfig(torn_checkpoint=0.1).enabled


# -- tenant circuit breaker -----------------------------------------------

def test_breaker_trips_cools_down_and_recovers():
    now = [0.0]
    b = TenantBreaker(fail_threshold=2, cooldown_s=1.0, max_trips=3,
                      clock=lambda: now[0])
    b.record_failure("t")
    assert b.poll() == [] and b.state("t") == CLOSED
    b.record_success("t")                    # success resets the streak
    b.record_failure("t")
    b.record_failure("t")
    assert b.poll() == [("t", OPEN)] and b.state("t") == OPEN
    assert b.poll() == []                    # cooldown not elapsed
    now[0] = 1.5
    assert b.poll() == [("t", HALF_OPEN)]
    b.record_success("t")                    # clean probe: full recovery
    assert b.poll() == [("t", CLOSED)]
    assert b.snapshot() == {}                # closed tenants don't surface


def test_breaker_retrip_abandon_and_straggler_noop():
    now = [0.0]
    b = TenantBreaker(fail_threshold=1, cooldown_s=1.0, max_trips=1,
                      clock=lambda: now[0])
    b.record_failure("t")
    assert b.poll() == [("t", OPEN)]
    # stragglers of the tripped tenant land while open: must not re-trip
    b.record_failure("t")
    b.record_failure("t")
    assert b.poll() == []
    now[0] = 2.0
    assert b.poll() == [("t", HALF_OPEN)]
    b.record_failure("t")                    # probe failed: trips(2) > 1
    assert b.poll() == [("t", ABANDONED)]
    b.record_failure("t")                    # terminal: further events noop
    b.record_success("t")
    assert b.poll() == [] and b.state("t") == ABANDONED
    assert b.snapshot() == {"t": ABANDONED}


def test_breaker_abandons_immediately_with_zero_trip_budget():
    b = TenantBreaker(fail_threshold=1, cooldown_s=1.0, max_trips=0)
    b.record_failure("t")
    assert b.poll() == [("t", ABANDONED)]


# -- checkpoint store: safe publish / retention / recovery ----------------

def _ck_mgr(**kw):
    m = MultiTaskManager(async_mode=True, max_staleness=1, **kw)
    m.submit(TaskSpec("t", "gsm8k", group_size=2, num_groups=2,
                      target_steps=100))
    m.admit("t")
    return m


def _ep(version, submit_index):
    return SimpleNamespace(version=version, submit_index=submit_index,
                           env=None, meta={})


def test_checkpoint_replace_leaves_no_aside(tmp_path):
    from repro.checkpoint.store import latest_checkpoint, save_checkpoint
    d = str(tmp_path)
    save_checkpoint(d, _ck_mgr(), step_tag="s")
    p = save_checkpoint(d, _ck_mgr(), step_tag="s")   # replace same tag
    assert latest_checkpoint(d) == p
    assert not [n for n in os.listdir(d) if n.endswith(".replacing")]


def test_checkpoint_keep_last_n_prunes_oldest(tmp_path):
    from repro.checkpoint.store import latest_checkpoint, save_checkpoint
    d = str(tmp_path)
    for i in range(4):
        time.sleep(0.01)                     # distinct manifest times
        p = save_checkpoint(d, _ck_mgr(), step_tag=f"s{i}", keep_last_n=2)
    snaps = sorted(n for n in os.listdir(d)
                   if os.path.isdir(os.path.join(d, n)))
    assert snaps == ["s2", "s3"]
    assert latest_checkpoint(d) == p


def test_latest_checkpoint_scans_when_pointer_is_bad(tmp_path):
    from repro.checkpoint.store import latest_checkpoint, save_checkpoint
    d = str(tmp_path)
    save_checkpoint(d, _ck_mgr(), step_tag="old")
    time.sleep(0.01)
    newest = save_checkpoint(d, _ck_mgr(), step_tag="new")
    os.remove(os.path.join(d, "LATEST"))     # missing pointer -> scan
    assert latest_checkpoint(d) == newest
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("no_such_snapshot")          # dangling pointer -> scan
    assert latest_checkpoint(d) == newest


def test_torn_checkpoint_falls_back_to_previous_snapshot(tmp_path):
    from repro.checkpoint.store import latest_checkpoint, save_checkpoint
    d = str(tmp_path)
    good = save_checkpoint(d, _ck_mgr(), step_tag="a")
    time.sleep(0.01)
    chaos = ChaosInjector(ChaosConfig(seed=0, torn_checkpoint=1.0,
                                      max_faults_per_site=1))
    with pytest.raises(ChaosError):
        save_checkpoint(d, _ck_mgr(), step_tag="b", chaos=chaos)
    # LATEST still points at `a`; the torn `b` dir has no manifest
    assert latest_checkpoint(d) == good
    # retry (cap exhausted -> no fault) publishes over the torn dir
    fixed = save_checkpoint(d, _ck_mgr(), step_tag="b", chaos=chaos)
    assert latest_checkpoint(d) == fixed


def test_checkpoint_async_episode_roundtrip(tmp_path):
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    m = _ck_mgr(min_train_rows=1)
    for g in range(2):                       # two complete groups...
        for i in range(2):
            m.enqueue_episode("t", 0, (0, g), _ep(0, g * 2 + i))
    popped_tid, popped = m.pop_episodes()    # ...popped but uncommitted
    m.enqueue_episode("t", 0, (0, 7), _ep(0, 8))
    m.enqueue_episode("t", 0, (0, 7), _ep(0, 9))   # one still queued
    m.tasks["t"].status = "quarantined"
    m.tasks["t"].failed_rows = 3
    m.failed_rows = 3
    m.quarantine_dropped_rows = 5
    path = save_checkpoint(str(tmp_path), m, step_tag="s")
    m2 = MultiTaskManager(async_mode=True, max_staleness=1)
    load_checkpoint(path, m2)
    # in-flight work restored at the queue head, same recover order
    assert [g.seq for g in m2.episodes["t"]] \
        == [g.seq for g in popped] + [g.seq for g in m.episodes["t"]]
    assert m2.ready_rows("t") == 6
    assert not m2._inflight_train            # restored AS queued work
    # fault counters survive the restart (invariant holds across it)
    assert m2.failed_rows == 3 and m2.tasks["t"].failed_rows == 3
    assert m2.quarantine_dropped_rows == 5
    # quarantine does not survive restart: no breaker would clear it
    assert m2.tasks["t"].status == "admitted"
    assert all(c.env is None for g in m2.episodes["t"] for c in g.rows)
    env = object()
    assert m2.rebind_episode_envs({"t": env}) == 6
    assert all(c.env is env for g in m2.episodes["t"] for c in g.rows)


def test_load_checkpoint_orphans_unserializable_completed_rows(tmp_path):
    """Rows completed before the crash whose round never assembled into a
    serialized batch/group regenerate after restart (their round
    re-issues) — load must attribute the lost copies to `orphaned_rows`
    so the conservation invariant stays exact across incarnations."""
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    m = _ck_mgr(min_train_rows=1)
    m.enqueue_episode("t", 0, (0, 0), _ep(0, 0))
    m.enqueue_episode("t", 0, (0, 0), _ep(0, 1))     # 2 rows survive
    m.rows_trained = 4                               # 2 commits survived
    m.tasks["t"].rollout_rows_total = 8              # ...but 8 completed
    path = save_checkpoint(str(tmp_path), m, step_tag="s")
    m2 = MultiTaskManager(async_mode=True, max_staleness=1)
    load_checkpoint(path, m2)
    # 8 completed = 4 trained + 2 in queues + 2 lost-to-the-crash
    assert m2.rows_trained == 4
    assert m2.orphaned_rows == 2
    # a second save/load round-trip must not re-count the same orphans
    path2 = save_checkpoint(str(tmp_path), m2, step_tag="s2")
    m3 = MultiTaskManager(async_mode=True, max_staleness=1)
    load_checkpoint(path2, m3)
    assert m3.orphaned_rows == 2


# -- env-stage tool-call retry / worker recovery --------------------------

class _FlakySession:
    """Session failing the first `fail` calls; optionally permanently."""

    def __init__(self, fail=0, permanent=False):
        self.fail = fail
        self.permanent = permanent
        self.calls = 0

    def call(self, query_ids, cancel=None):
        self.calls += 1
        if self.permanent:
            raise PermanentToolError("endpoint down")
        if self.calls <= self.fail:
            raise TransientToolError("flaky")
        return [4, 2]


def _stage(**kw):
    from repro.rollout.env_stage import EnvStage
    kw.setdefault("retry_base_s", 0.001)
    kw.setdefault("retry_max_s", 0.01)
    return EnvStage(n_workers=1, **kw)


def _drain_one(stage, deadline_s=10.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        out = stage.drain_resolved()
        if out:
            return out[0]
        time.sleep(0.002)
    raise AssertionError("env stage never resolved the job")


def test_env_stage_retries_transient_then_succeeds():
    stage = _stage(retry_max=3)
    sess = _FlakySession(fail=2)
    row = SimpleNamespace(session=sess, tool_retries=0)
    stage.submit(row, [1, 2], "t", latency=0.0)
    job = _drain_one(stage)
    assert job.error is None and job.response == [4, 2]
    assert sess.calls == 3 and stage.retries == 2
    assert row.tool_retries == 2
    stage.halt(timeout_s=10.0)


def test_env_stage_fails_row_when_retry_budget_spent():
    stage = _stage(retry_max=2)
    sess = _FlakySession(fail=99)
    stage.submit(SimpleNamespace(session=sess, tool_retries=0),
                 [1], "t", latency=0.0)
    job = _drain_one(stage)
    assert isinstance(job.error, TransientToolError)
    assert job.response == [] and sess.calls == 3     # 1 try + 2 retries
    stage.halt(timeout_s=10.0)


def test_env_stage_permanent_error_fails_without_retry():
    stage = _stage(retry_max=3)
    sess = _FlakySession(permanent=True)
    stage.submit(SimpleNamespace(session=sess, tool_retries=0),
                 [1], "t", latency=0.0)
    job = _drain_one(stage)
    assert isinstance(job.error, PermanentToolError)
    assert sess.calls == 1 and stage.retries == 0
    stage.halt(timeout_s=10.0)


def test_env_stage_episode_retry_cap_bounds_flapping_rows():
    stage = _stage(retry_max=5, retry_episode_cap=1)
    sess = _FlakySession(fail=99)
    row = SimpleNamespace(session=sess, tool_retries=0)
    stage.submit(row, [1], "t", latency=0.0)
    job = _drain_one(stage)
    assert isinstance(job.error, TransientToolError)
    assert row.tool_retries == 1                      # cap, not retry_max
    stage.halt(timeout_s=10.0)


def test_env_stage_injected_transient_fault_passes_through():
    chaos = ChaosInjector(ChaosConfig(seed=0, tool_error_transient=1.0,
                                      transient_fail_count=2,
                                      max_faults_per_site=1))
    stage = _stage(retry_max=3, chaos=chaos)
    sess = _FlakySession()
    stage.submit(SimpleNamespace(session=sess, tool_retries=0),
                 [1], "t", latency=0.0)
    job = _drain_one(stage)
    # both injected failures precede any real call; the retry then lands
    assert job.error is None and job.response == [4, 2]
    assert sess.calls == 1 and stage.retries == 2
    assert chaos.counts() == {"tool_error_transient": 1}
    stage.halt(timeout_s=10.0)


def test_env_stage_recovers_job_from_chaos_killed_worker():
    chaos = ChaosInjector(ChaosConfig(seed=0, env_worker_kill=1.0,
                                      max_faults_per_site=1))
    stage = _stage(chaos=chaos)
    sess = _FlakySession()
    stage.submit(SimpleNamespace(session=sess, tool_retries=0),
                 [1], "t", latency=0.0)
    t0 = time.monotonic()
    while stage.healthy() and time.monotonic() - t0 < 10.0:
        time.sleep(0.002)
    assert not stage.healthy()               # worker died mid-job
    assert stage.recover_dead() == 1         # stranded job re-queued
    stage._ensure_workers()                  # supervisor respawn path
    job = _drain_one(stage)
    assert job.error is None and job.response == [4, 2]
    assert stage.recovered == 1 and sess.calls == 1
    stage.halt(timeout_s=10.0)


# -- RA106: swallowed exceptions in worker run() loops --------------------

_RA106_BAD = '''
import threading

class W(threading.Thread):
    def run(self):
        while True:
            try:
                self.step()
            except:
                pass

class X(threading.Thread):
    def run(self):
        try:
            self.step()
        except Exception:
            return
'''

_RA106_GOOD = '''
import threading

class Y(threading.Thread):
    def run(self):
        try:
            self.step()
        except ValueError:
            pass                    # narrow taxonomy: never flagged
        try:
            self.step()
        except Exception as e:
            self.error = e          # recorded for the supervisor
        try:
            self.step()
        except BaseException:
            raise

class NotAWorker:
    def run(self):
        try:
            self.step()
        except Exception:
            pass                    # not a Thread subclass
'''


def test_ra106_flags_swallowed_worker_exceptions(tmp_path):
    from repro.analysis.core import collect_files
    from repro.analysis.robustness import check
    bad = tmp_path / "bad.py"
    bad.write_text(_RA106_BAD)
    good = tmp_path / "good.py"
    good.write_text(_RA106_GOOD)
    findings = check(collect_files([str(bad), str(good)]))
    assert sorted(f.rule for f in findings) == ["RA106", "RA106"]
    assert all(f.file == "bad.py" for f in findings)
    msgs = " ".join(f.message for f in findings)
    assert "W.run()" in msgs and "X.run()" in msgs


def test_ra106_runs_in_the_analysis_gate(tmp_path):
    from repro.analysis.core import analyze_paths
    bad = tmp_path / "worker.py"
    bad.write_text(_RA106_BAD)
    findings, _ = analyze_paths([str(bad)])
    assert any(f.rule == "RA106" for f in findings)


# -- observability: breaker timeline + trace fault report -----------------

def test_metrics_breaker_timeline():
    from repro.core.metrics import MetricsRecorder
    rec = MetricsRecorder({"rollout": 1})
    rec.record_breaker_sample(1.0, "a", OPEN)
    rec.record_breaker_sample(2.0, "b", OPEN)
    rec.record_breaker_sample(3.0, "a", CLOSED)
    assert rec.breaker_timeline("a") == [(1.0, "a", OPEN),
                                         (3.0, "a", CLOSED)]
    assert len(rec.breaker_timeline()) == 3


def test_report_load_faults_from_trace():
    from repro.obs.report import load_faults
    trace = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 5,
         "args": {"name": "supervisor:env_worker"}},
        {"ph": "i", "cat": "supervisor", "pid": 1, "tid": 5,
         "name": "restart", "ts": 0},
        {"ph": "i", "cat": "supervisor", "pid": 1, "tid": 5,
         "name": "restart", "ts": 1},
        {"ph": "i", "cat": "supervisor", "pid": 1, "tid": 9,
         "name": "hop:open", "ts": 2},
        {"ph": "i", "cat": "supervisor", "pid": 1, "tid": 9,
         "name": "hop:half_open", "ts": 3},
        {"ph": "i", "cat": "other", "pid": 1, "tid": 9,
         "name": "ignored", "ts": 4},
    ]}
    out = load_faults(trace)
    assert out["stage_restarts"] == {"supervisor:env_worker": 2}
    assert out["breaker_transitions"] == {"hop": ["open", "half_open"]}


# -- runtime chaos matrix (real engine, slow) -----------------------------

_CACHE = {}


def _force_calls(monkeypatch, call_at=2):
    """Deterministic forced-CALL pattern (the bench_async_train idiom):
    every row samples CALL at token counter `call_at` (a plain token for
    non-agentic tenants) and EOS is remapped away. Tool calls must not
    depend on what the randomly-initialized model happens to sample —
    prompt datagens are seeded via process-salted hash()."""
    import jax.numpy as jnp

    import repro.rollout.engine as eng_mod
    import repro.rollout.prefill as pf_mod
    from repro.data import tokenizer as tok
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        return jnp.where(counters == call_at, tok.CALL, s)

    monkeypatch.setattr(pf_mod, "_sample_rows", biased)
    monkeypatch.setattr(eng_mod, "_sample_rows", biased)


def _chaos_runtime(seed=3, chaos=None, **over):
    """The test_env_stage e2e config (agentic + plain tenant, all three
    stages disaggregated) with a chaos script layered on."""
    import jax
    from conftest import tiny_lm
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    from repro.models import init_params
    if "p" not in _CACHE:
        cfg = tiny_lm("granite-3-2b")
        _CACHE["p"] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    cfg, params = _CACHE["p"]
    rcfg = RuntimeConfig(policy="marlaas", max_len=64, seed=seed,
                         max_slots=4, disagg_prefill=True, prefill_workers=1,
                         env_stage=True, env_workers=2, max_turns=2,
                         chaos=chaos, tool_retry_base_s=0.01,
                         tool_retry_max_s=0.05, **over)
    rt = MARLaaSRuntime(cfg, params, rcfg)
    rt.submit_task(TaskSpec("hop", "hopsearch", group_size=2, num_groups=1,
                            max_new_tokens=6, target_steps=2))
    rt.submit_task(TaskSpec("gsm", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=4, target_steps=2))
    return rt


@pytest.mark.slow
def test_runtime_survives_stage_worker_kills(monkeypatch):
    """Prefill + env workers killed mid-job: the supervisor recovers the
    stranded work and respawns; the run still completes and every row is
    accounted for."""
    _force_calls(monkeypatch)
    rt = _chaos_runtime(chaos=ChaosConfig(
        seed=0, prefill_worker_kill=1.0, env_worker_kill=1.0,
        max_faults_per_site=1))
    rt.run(timeout_s=300.0)
    assert rt.error is None
    assert all(st.done for st in rt.mgr.tasks.values())
    c = rt.rec.counters_snapshot()
    assert rt.chaos.counts().get("prefill_worker_kill") == 1
    assert rt.chaos.counts().get("env_worker_kill") == 1
    assert c.get("supervisor_prefill_worker_restarts", 0) >= 1
    assert c.get("supervisor_env_worker_restarts", 0) >= 1
    assert c.get("supervisor_env_worker_jobs_recovered", 0) >= 1
    _assert_accounting(rt)


@pytest.mark.slow
def test_runtime_transient_tool_errors_are_bit_identical(monkeypatch):
    """Transient tool faults that retry to success leave the token stream
    (rewards, trained adapters) bit-identical to the fault-free run."""
    import jax
    _force_calls(monkeypatch)
    base = _chaos_runtime(chaos=None)
    base.run(timeout_s=300.0)
    assert base.error is None and all(st.done
                                      for st in base.mgr.tasks.values())
    faulty = _chaos_runtime(chaos=ChaosConfig(
        seed=0, tool_error_transient=1.0, transient_fail_count=1,
        max_faults_per_site=2))
    faulty.run(timeout_s=300.0)
    assert faulty.error is None
    assert all(st.done for st in faulty.mgr.tasks.values())
    assert faulty.chaos.counts().get("tool_error_transient") == 2
    assert faulty.rec.counters_snapshot().get("env_retries", 0) >= 1
    for tid in ("hop", "gsm"):
        a, b = base.mgr.state(tid), faulty.mgr.state(tid)
        assert a.reward_history == b.reward_history
        for x, y in zip(jax.tree.leaves(a.adapters),
                        jax.tree.leaves(b.adapters)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    _assert_accounting(base)
    _assert_accounting(faulty)


@pytest.mark.slow
def test_runtime_quarantine_recovers_after_transient_outage(monkeypatch):
    """A capped permanent-fault burst trips the agentic tenant's breaker;
    after the cooldown the probe round succeeds, the breaker closes, and
    the tenant still trains to target."""
    _force_calls(monkeypatch)
    rt = _chaos_runtime(chaos=ChaosConfig(
        seed=0, tool_error_permanent=1.0, max_faults_per_site=1),
        breaker_fail_threshold=1, breaker_cooldown_s=0.2,
        breaker_max_trips=3)
    rt.run(timeout_s=300.0)
    assert rt.error is None
    assert all(st.done for st in rt.mgr.tasks.values())
    assert not rt.mgr.tasks["hop"].abandoned
    assert rt.mgr.tasks["hop"].steps_done == 2
    assert rt.breaker.state("hop") == CLOSED
    c = rt.rec.counters_snapshot()
    assert c.get("quarantine_trips", 0) >= 1
    assert c.get("quarantine_recoveries", 0) >= 1
    states = [s for _, _, s in rt.rec.breaker_timeline("hop")]
    assert states[:3] == [OPEN, HALF_OPEN, CLOSED]
    d = rt.mgr.drop_counters()
    assert d["failed_rows"] >= 1
    _assert_accounting(rt)


@pytest.mark.slow
def test_runtime_abandons_tenant_with_persistent_tool_outage(monkeypatch):
    """Uncapped permanent tool errors: the agentic tenant exhausts its
    trip budget and is abandoned; the healthy plain tenant trains to
    target and the run completes without wedging."""
    _force_calls(monkeypatch)
    rt = _chaos_runtime(chaos=ChaosConfig(seed=0, tool_error_permanent=1.0),
                        breaker_fail_threshold=1, breaker_max_trips=0)
    rt.run(timeout_s=300.0)
    assert rt.error is None
    assert all(st.done for st in rt.mgr.tasks.values())
    assert rt.mgr.tasks["hop"].abandoned
    assert rt.mgr.tasks["hop"].steps_done < 2
    assert rt.mgr.tasks["gsm"].steps_done == 2
    assert not rt.mgr.tasks["gsm"].abandoned
    assert rt.breaker.state("hop") == ABANDONED
    assert rt.rec.counters_snapshot().get("quarantine_abandoned", 0) >= 1
    d = rt.mgr.drop_counters()
    assert d["failed_rows"] >= 1
    _assert_accounting(rt)
