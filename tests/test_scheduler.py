"""Slot-scheduler policies (SRPT pop order, priority tiers, starvation
bound, deterministic ties), the EMA length predictor, LRU adapter
residency, and the admission controller's preempt/readmit accounting."""
from types import SimpleNamespace

import pytest

from repro.configs import get_config
from repro.core.admission import AdmissionConfig, AdmissionController
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.lora.multilora import AdapterResidency
from repro.rollout.scheduler import LengthPredictor, SlotScheduler

_IDX = [0]


def row(task="t", priority=0, budget=16, sampled=0):
    """Duck-typed stand-in for the engine's _Row (unique submit_index)."""
    _IDX[0] += 1
    return SimpleNamespace(
        req=SimpleNamespace(task_id=task, priority=priority,
                            max_new_tokens=budget),
        sampled=sampled, submit_index=_IDX[0])


# -- LengthPredictor ------------------------------------------------------

def test_predictor_prior_is_budget_then_ema():
    p = LengthPredictor(alpha=0.5)
    assert p.predict("a", 32) == 32.0            # cold tenant: full budget
    p.observe("a", 8)
    assert p.predict("a", 32) == 8.0
    p.observe("a", 16)
    assert p.predict("a", 32) == 12.0            # 0.5*16 + 0.5*8
    assert p.predict("a", 10) == 10.0            # capped by the row's budget
    assert p.predict("b", 32) == 32.0            # other tenants unaffected


def test_predictor_remaining_credits_replayed_prefix():
    p = LengthPredictor()
    p.observe("a", 20)
    assert p.remaining("a", 64, sampled=15) == 5.0
    assert p.remaining("a", 64, sampled=25) == 1.0   # floor at 1


# -- SRPT pop ordering ----------------------------------------------------

def test_srpt_pops_shortest_remaining_budget_first():
    s = SlotScheduler(policy="srpt")
    long_, short, mid = row(budget=64), row(budget=4), row(budget=16)
    for r in (long_, short, mid):
        s.push(r)
    assert s.pop() is short
    assert s.pop() is mid
    assert s.pop() is long_
    assert s.pop() is None


def test_srpt_uses_predicted_not_nominal_length():
    p = LengthPredictor()
    p.observe("chatty", 6)              # big budget but short in practice
    s = SlotScheduler(policy="srpt", predictor=p)
    nominal_short = row(task="fresh", budget=10)
    learned_short = row(task="chatty", budget=64)
    s.push(nominal_short)
    s.push(learned_short)
    assert s.pop() is learned_short     # predicted 6 < fresh prior 10


def test_priority_tiers_dominate_remaining():
    s = SlotScheduler(policy="srpt")
    low_short = row(priority=0, budget=2)
    high_long = row(priority=5, budget=64)
    s.push(low_short)
    s.push(high_long)
    assert s.pop() is high_long         # tier first, length second


def test_deterministic_tie_break_on_submit_index():
    s = SlotScheduler(policy="srpt")
    rows = [row(task="t", budget=8) for _ in range(5)]
    for r in reversed(rows):            # push in reverse submit order
        s.push(r)
    assert [s.pop() for _ in range(5)] == rows


def test_fifo_policy_preserves_arrival_order():
    s = SlotScheduler(policy="fifo")
    a, b, c = row(budget=64), row(budget=2), row(budget=8)
    for r in (a, b, c):
        s.push(r)
    assert [s.pop(), s.pop(), s.pop()] == [a, b, c]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="policy"):
        SlotScheduler(policy="wfq")


def test_starvation_bound_every_tenant_progresses_within_k_refills():
    """A long row can be bypassed by newly-arriving short rows at most
    `starvation_k` times: after K refill events it jumps to the starved
    tier and pops before any fresh short row."""
    K = 4
    s = SlotScheduler(policy="srpt", starvation_k=K)
    long_ = row(task="long", budget=100)
    s.push(long_, refill_count=0)
    popped_at = None
    for refill in range(20):
        # adversary: two fresh short rows arrive every refill
        s.push(row(task="spam", budget=1), refill_count=refill)
        s.push(row(task="spam", budget=1), refill_count=refill)
        got = s.pop(refill_count=refill)
        if got is long_:
            popped_at = refill
            break
    assert popped_at is not None and popped_at <= K, popped_at


def test_starved_rows_pop_fifo_among_themselves():
    s = SlotScheduler(policy="srpt", starvation_k=2)
    first = row(task="a", budget=50)
    second = row(task="b", budget=1)    # shorter, but starved later
    s.push(first, refill_count=0)
    s.push(second, refill_count=0)
    assert s.pop(refill_count=10) is first     # both starved: FIFO
    assert s.pop(refill_count=10) is second


# -- AdapterResidency (LRU) ----------------------------------------------

def test_residency_lru_evicts_least_recently_used_idle_tenant():
    installed = {}
    res = AdapterResidency(2, lambda slot, tree: installed.update({slot: tree}))
    assert res.acquire("a", "tree-a") == 0
    assert res.acquire("b", "tree-b") == 1
    res.touch("a")                      # b is now LRU
    slot = res.acquire("c", "tree-c")
    assert slot == 1 and installed[1] == "tree-c"
    assert res.resident() == {"a": 0, "c": 1}
    assert res.evictions == 1 and res.installs == 3


def test_residency_never_evicts_in_use_tenant():
    res = AdapterResidency(1, lambda slot, tree: None)
    res.acquire("busy", "t1")
    assert res.acquire("new", "t2", in_use=lambda t: t == "busy") is None
    assert res.resident() == {"busy": 0}
    assert res.acquire("new", "t2") == 0          # evictable once idle
    assert res.slot_of("busy") is None


def test_residency_hit_does_not_reinstall():
    res = AdapterResidency(2, lambda slot, tree: None)
    res.acquire("a", "t")
    res.acquire("a", "t")
    assert res.installs == 1 and res.hits == 1


# -- AdmissionController preempt/readmit accounting -----------------------

def _strict_controller(budget):
    return AdmissionController(
        get_config("granite-3-2b"),
        AdmissionConfig(memory_budget_bytes=budget, strict=True))


def test_admission_preempt_releases_bytes_regression():
    """Regression: preempted (not finished) tasks must release their
    reservation — previously bytes were only dropped at task finish, so
    preemption could never create admission capacity."""
    ac = _strict_controller(100)
    assert ac.try_admit_bytes("a", 80)
    assert not ac.try_admit_bytes("b", 80)       # no room
    assert ac.preempt("a") == 80
    assert ac.used_bytes == 0                    # bytes actually freed
    assert ac.try_admit_bytes("b", 80)           # newcomer fits now
    assert ac.admitted() == ["b"] and ac.preempted() == ["a"]


def test_admission_readmit_recharges_same_estimate():
    ac = _strict_controller(100)
    ac.try_admit_bytes("a", 60)
    ac.preempt("a")
    ac.try_admit_bytes("b", 70)
    assert not ac.try_readmit("a")               # 70 + 60 > 100
    ac.release("b")
    assert ac.try_readmit("a")
    assert ac.used_bytes == 60 and ac.preempted() == []


def test_admission_release_clears_preempted_reservation():
    ac = _strict_controller(100)
    ac.try_admit_bytes("a", 60)
    ac.preempt("a")
    ac.release("a")                              # finished while preempted
    assert ac.preempted() == [] and not ac.try_readmit("a")


def test_preempt_unknown_or_pending_task_is_noop():
    ac = _strict_controller(100)
    assert ac.preempt("ghost") == 0
    assert ac.used_bytes == 0


# -- manager preemption state machine -------------------------------------

def test_manager_preempt_blocks_new_rounds_until_readmit():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k", target_steps=5))
    m.admit("t")
    assert m.preempt("t")
    assert m.tasks["t"].status == "preempted"
    assert m.tasks["t"].preempt_count == 1
    assert m.next_policy("t") is None            # no NEW rollout rounds
    assert m.rollout_ready_tasks() == []
    assert m.readmit("t")
    assert m.next_policy("t") == (0, None)       # unblocked
    assert not m.readmit("t")                    # only from preempted


def test_manager_adapter_residency_bookkeeping():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k"))
    m.adapter_bound("t", 3)
    assert m.resident_adapters() == {"t": 3}
    assert m.tasks["t"].adapter_installs == 1
    m.adapter_unbound("t")
    assert m.resident_adapters() == {}
