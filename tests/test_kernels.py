"""Pallas kernels vs pure-jnp oracles (ref.py) across shape/dtype sweeps
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.sgmv import sgmv
from repro.kernels.gqa_decode import gqa_decode
from repro.kernels.paged_decode import paged_gqa_decode
from repro.kernels.token_logprob import token_logprob_flat

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("R,d,r,dout,T", [
    (32, 64, 8, 48, 3), (100, 256, 16, 512, 5), (17, 48, 4, 40, 2),
    (64, 128, 32, 256, 8), (8, 72, 8, 72, 1), (256, 64, 8, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sgmv_sweep(R, d, r, dout, T, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (R, d), dtype)
    a = (jax.random.normal(ks[1], (T, d, r), jnp.float32) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[2], (T, r, dout), jnp.float32) * 0.1).astype(dtype)
    ids = jax.random.randint(ks[3], (R,), 0, T)
    y = sgmv(x, a, b, ids)
    want = ref.sgmv_ref(x, a, b, ids)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_sgmv_empty_group():
    """Tasks with zero rows must not corrupt neighbours."""
    x = jax.random.normal(KEY, (24, 32), jnp.float32)
    a = jax.random.normal(KEY, (4, 32, 4), jnp.float32) * 0.1
    b = jax.random.normal(KEY, (4, 4, 16), jnp.float32) * 0.1
    ids = jnp.array([0] * 12 + [3] * 12)          # groups 1, 2 empty
    np.testing.assert_allclose(np.asarray(sgmv(x, a, b, ids)),
                               np.asarray(ref.sgmv_ref(x, a, b, ids)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,KVH,hd,S", [
    (2, 4, 2, 16, 64), (3, 8, 2, 32, 128), (2, 4, 4, 16, 64),
    (1, 12, 2, 16, 96), (2, 16, 8, 64, 256),
])
@pytest.mark.parametrize("softcap,window", [(0.0, 0), (50.0, 0), (0.0, 24)])
def test_gqa_decode_sweep(B, H, KVH, hd, S, softcap, window):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
    pos = jax.random.randint(ks[3], (B,), 1, S)
    out = gqa_decode(q, ck, cv, pos, bs=32, softcap=softcap, window=window)
    want = ref.gqa_decode_ref(q, ck, cv, pos, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gqa_decode_bf16_cache():
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (2, 4, 16), jnp.bfloat16)
    ck = jax.random.normal(ks[1], (2, 64, 2, 16), jnp.bfloat16)
    cv = jax.random.normal(ks[2], (2, 64, 2, 16), jnp.bfloat16)
    pos = jnp.array([13, 64])
    out = gqa_decode(q, ck, cv, pos, bs=32)
    want = ref.gqa_decode_ref(q, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def _paged_cache(key, B, n_pg, page, KVH, hd, dtype):
    """Random page pool + per-row block tables: rows own disjoint physical
    pages in shuffled order (plus the scratch page at index P)."""
    ks = jax.random.split(key, 3)
    P = B * n_pg + 3                      # a few never-owned pages too
    kp = jax.random.normal(ks[0], (P + 1, page, KVH, hd), dtype)
    vp = jax.random.normal(ks[1], (P + 1, page, KVH, hd), dtype)
    perm = np.asarray(jax.random.permutation(ks[2], P))[:B * n_pg]
    tbl = jnp.asarray(perm.reshape(B, n_pg).astype(np.int32))
    return kp, vp, tbl


@pytest.mark.parametrize("B,H,KVH,hd,n_pg,page", [
    (2, 4, 2, 16, 4, 16), (3, 8, 2, 32, 2, 64), (2, 4, 4, 16, 8, 8),
    (1, 12, 2, 16, 3, 32), (2, 16, 8, 64, 2, 128),
])
@pytest.mark.parametrize("softcap,window", [(0.0, 0), (50.0, 0), (0.0, 24)])
def test_paged_gqa_decode_sweep(B, H, KVH, hd, n_pg, page, softcap, window):
    """Paged flash-decode (block table via scalar prefetch) vs the
    gather-then-dense oracle across the gqa_decode sweep shapes."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kp, vp, tbl = _paged_cache(ks[1], B, n_pg, page, KVH, hd, jnp.float32)
    pos = jax.random.randint(ks[2], (B,), 1, n_pg * page)
    out = paged_gqa_decode(q, kp, vp, tbl, pos, softcap=softcap,
                           window=window)
    want = ref.paged_gqa_decode_ref(q, kp, vp, tbl, pos, softcap=softcap,
                                    window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_gqa_decode_bf16_cache():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 16), jnp.bfloat16)
    kp, vp, tbl = _paged_cache(ks[1], 2, 4, 16, 2, 16, jnp.bfloat16)
    pos = jnp.array([13, 64])
    out = paged_gqa_decode(q, kp, vp, tbl, pos)
    want = ref.paged_gqa_decode_ref(q, kp, vp, tbl, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_paged_gqa_decode_matches_contiguous():
    """A paged cache whose block table is the identity must reproduce the
    contiguous gqa_decode kernel exactly (same tiles, different routing)."""
    ks = jax.random.split(KEY, 4)
    B, H, KVH, hd, n_pg, page = 2, 8, 2, 32, 4, 32
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, n_pg * page, KVH, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, n_pg * page, KVH, hd), jnp.float32)
    pos = jax.random.randint(ks[3], (B,), 1, n_pg * page)
    # lay the contiguous caches out as pages: row b owns pages b*n_pg..
    kp = jnp.concatenate([ck.reshape(B * n_pg, page, KVH, hd),
                          jnp.zeros((1, page, KVH, hd), jnp.float32)])
    vp = jnp.concatenate([cv.reshape(B * n_pg, page, KVH, hd),
                          jnp.zeros((1, page, KVH, hd), jnp.float32)])
    tbl = jnp.arange(B * n_pg, dtype=jnp.int32).reshape(B, n_pg)
    out = paged_gqa_decode(q, kp, vp, tbl, pos)
    want = gqa_decode(q, ck, cv, pos, bs=page)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_gqa_decode_aliased_block_tables():
    """COW prefix sharing (ISSUE 8) makes block tables alias the SAME
    physical pages across rows: every row of a GRPO group points its
    prompt-prefix entries at one shared page set and only the tail pages
    are private. The kernel indexes the pool through the per-row table, so
    aliased rows must read identically to rows with private copies of the
    same values — and rows at different positions within the shared pages
    must each mask correctly."""
    ks = jax.random.split(KEY, 4)
    B, H, KVH, hd, n_pg, page = 4, 8, 2, 32, 4, 16
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    # pool: 2 shared prefix pages + B private tail-region pages (+ scratch)
    P = 2 + 2 * B
    kp = jax.random.normal(ks[1], (P + 1, page, KVH, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P + 1, page, KVH, hd), jnp.float32)
    tbl = np.zeros((B, n_pg), np.int32)
    tbl[:, :2] = [0, 1]                       # all rows share pages 0,1
    for b in range(B):
        tbl[b, 2:] = [2 + 2 * b, 3 + 2 * b]   # private tails
    # rows at different depths, including one still inside the shared pages
    pos = jnp.array([page + 3, 2 * page, 3 * page + 5, 4 * page - 1])
    out = paged_gqa_decode(q, kp, vp, jnp.asarray(tbl), pos)
    want = ref.paged_gqa_decode_ref(q, kp, vp, jnp.asarray(tbl), pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # aliasing is value-transparent: materialize private copies of the
    # shared pages per row and the outputs must match bit-for-bit
    kp2, vp2 = np.asarray(kp), np.asarray(vp)
    kp2 = np.concatenate([kp2, kp2[[0, 1]].repeat(B, 0).reshape(
        2 * B, page, KVH, hd)])
    vp2 = np.concatenate([vp2, vp2[[0, 1]].repeat(B, 0).reshape(
        2 * B, page, KVH, hd)])
    tbl2 = tbl.copy()
    for b in range(B):
        tbl2[b, :2] = [P + 1 + b, P + 1 + B + b]
    out2 = paged_gqa_decode(q, jnp.asarray(kp2), jnp.asarray(vp2),
                            jnp.asarray(tbl2), pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


@pytest.mark.parametrize("R,d,V", [(16, 32, 64), (50, 48, 100), (8, 24, 52),
                                   (128, 64, 512)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_token_logprob_sweep(R, d, V, softcap):
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (R, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.3
    t = jax.random.randint(ks[2], (R,), 0, V)
    lp, ent = token_logprob_flat(h, w, t, bm=8, bv=32, bk=16, softcap=softcap)
    want_lp, want_ent = ref.token_logprob_ref(h, w, t, softcap)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want_lp),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want_ent),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_shapes():
    """ops.py public API: [B, S, ...] wrappers."""
    B, S, d, V = 2, 8, 16, 40
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    w = jax.random.normal(ks[1], (d, V), jnp.float32)
    t = jax.random.randint(ks[2], (B, S), 0, V)
    lp, ent = ops.token_logprob(h, w, t)
    assert lp.shape == (B, S) and ent.shape == (B, S)
    want_lp, _ = ref.token_logprob_ref(h, w, t)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want_lp),
                               rtol=1e-4, atol=1e-4)
