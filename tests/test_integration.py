"""End-to-end system behaviour: the real threaded runtime (actual JAX
rollout + GRPO), fault injection + restart, and the agentic tool path."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_lm
from repro.checkpoint.store import (latest_checkpoint, load_checkpoint,
                                    save_checkpoint)
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.core.metrics import summarize
from repro.core.runtime import FailureInjector, MARLaaSRuntime, RuntimeConfig
from repro.models import init_params

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def base():
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _specs(n_steps=2):
    return [
        TaskSpec("gsm-0", "gsm8k", group_size=2, num_groups=1,
                 max_new_tokens=5, target_steps=n_steps),
        TaskSpec("amc-0", "amc12", group_size=2, num_groups=1,
                 max_new_tokens=6, target_steps=n_steps),
    ]


def test_async_runtime_completes_and_is_on_policy(base):
    cfg, params = base
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(policy="marlaas",
                                                   max_len=48, seed=0))
    for s in _specs():
        rt.submit_task(s)
    rt.run(timeout_s=300)
    assert rt.mgr.all_done()
    for st in rt.mgr.tasks.values():
        assert st.version == st.steps_done == st.spec.target_steps
    s = summarize(rt.mgr, rt.rec)
    assert s["total_steps"] == 4 and s["ttfs_mean_s"] > 0


def test_sync_and_sequential_policies_complete(base):
    cfg, params = base
    for pol in ("multilora_sync", "single_disagg"):
        rt = MARLaaSRuntime(cfg, params, RuntimeConfig(policy=pol,
                                                       max_len=48, seed=1))
        for s in _specs(1):
            rt.submit_task(s)
        rt.run(timeout_s=300)
        assert rt.mgr.all_done(), pol


def test_failure_restart_resumes_exactly(base, tmp_path):
    """Crash mid-run, restore from the atomic snapshot, finish: versions and
    adapter state must continue from the last committed step."""
    cfg, params = base
    ckpt = str(tmp_path / "ckpt")
    rt = MARLaaSRuntime(cfg, params,
                        RuntimeConfig(policy="marlaas", max_len=48, seed=2,
                                      checkpoint_dir=ckpt, checkpoint_every=1),
                        failure=FailureInjector(fail_after_commits=2))
    for s in _specs(3):
        rt.submit_task(s)
    with pytest.raises(RuntimeError, match="injected node failure"):
        rt.run(timeout_s=300)
    assert latest_checkpoint(ckpt) is not None

    rt2 = MARLaaSRuntime(cfg, params, RuntimeConfig(policy="marlaas",
                                                    max_len=48, seed=3))
    load_checkpoint(latest_checkpoint(ckpt), rt2.mgr)
    pre_steps = sum(st.steps_done for st in rt2.mgr.tasks.values())
    assert pre_steps >= 1
    for tid, st in rt2.mgr.tasks.items():     # envs/datagens for loaded tasks
        from repro.envs.tasks import make_env
        import random
        rt2.envs[tid] = make_env(st.spec.env_name)
        rt2.datagens[tid] = random.Random(7)
    rt2.run(timeout_s=300)
    assert rt2.mgr.all_done()
    for st in rt2.mgr.tasks.values():
        assert st.steps_done == st.spec.target_steps


def test_agentic_tool_call_freezes_and_resumes(base):
    """Force a CALL token mid-generation; the engine must dispatch the tool,
    freeze the row, force-feed the response with loss_mask=0, and resume."""
    import random
    from repro.data import tokenizer as tok
    from repro.envs.tasks import make_env
    from repro.rollout.engine import (RolloutEngine, RolloutRequest,
                                      to_trajectory_batch)
    cfg, params = base
    env = make_env("search", kb_size=8)
    env.env_latency_mean = 0.05
    rng = random.Random(0)
    prompt, truth = env.sample_prompt(rng)
    eng = RolloutEngine(cfg, params, max_len=64, seed=0)
    eng._build(1)
    orig_step = eng._step_fn
    count = {"n": 0}

    def forced_call_step(*args):
        nxt, lp, cache = orig_step(*args)
        count["n"] += 1
        if count["n"] == 2:                   # second decode step emits CALL
            nxt = jnp.full_like(nxt, tok.CALL)
        return nxt, lp, cache

    eng._step_fn = forced_call_step
    reqs = [RolloutRequest("s", 0, prompt, truth, env, max_new_tokens=12)]
    from repro.lora.adapters import init_lora
    res, stats = eng.generate(reqs, [init_lora(jax.random.PRNGKey(1), cfg)])
    assert stats.env_wait_seconds > 0, "tool call never dispatched"
    toks = res[0]["tokens"]
    assert tok.CALL in toks and tok.RESP in toks and tok.ENDRESP in toks
    tb = to_trajectory_batch(res, "s", 0, 1)
    lm = tb.meta["loss_mask"]
    # force-fed RESP tokens carry zero loss
    resp_positions = [i for i, t in enumerate(toks) if t in
                      (tok.RESP, tok.ENDRESP)]
    assert all(lm[0, p - 1] == 0.0 for p in resp_positions)


def test_straggler_budget_returns_partial_rows(base):
    """Rows that never emit EOS finish at the token budget (no stall)."""
    cfg, params = base
    from repro.envs.tasks import make_env
    from repro.rollout.engine import RolloutEngine, RolloutRequest
    from repro.lora.adapters import init_lora
    import random
    env = make_env("gsm8k")
    rng = random.Random(1)
    prompt, truth = env.sample_prompt(rng)
    eng = RolloutEngine(cfg, params, max_len=64, seed=5)
    reqs = [RolloutRequest("g", 0, prompt, truth, env, max_new_tokens=4)]
    res, stats = eng.generate(reqs, [init_lora(jax.random.PRNGKey(2), cfg)])
    assert len(res[0]["tokens"]) <= len(prompt) + 4 + 33
