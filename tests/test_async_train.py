"""Event-driven off-policy trainer with bounded staleness (ROADMAP §2).

Manager-level: GRPO-group assembly in the per-tenant episode queue,
drop-or-train staleness admission at enqueue AND pop time, micro-batch
threshold rounding, pop deadline semantics under unrelated wake-ups, and
in-flight train-work recovery after a trainer crash.

Runtime-level (slow): the hypothesis property that ``max_staleness=0``
async training is bit-identical to the round-synchronous baseline across
attention / SSM / hybrid families; a pre-commit trainer crash + in-memory
restart finishing without losing the popped work; and the clean-drain
row-accounting invariant of a pipelined (``max_staleness>0``) run.
"""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.manager import MultiTaskManager, TaskSpec

FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}


def _tb(tid, v, rows=2):
    from repro.rl.types import TrajectoryBatch
    z = np.zeros((rows, 4), np.float32)
    return TrajectoryBatch(task_id=tid, version=v,
                           tokens=z.astype(np.int32),
                           prompt_lens=np.ones(rows, np.int32),
                           total_lens=np.full(rows, 3, np.int32),
                           rewards=np.zeros(rows, np.float32), group_size=2)


def _ep(version, submit_index):
    return SimpleNamespace(version=version, submit_index=submit_index)


def _mgr(**kw):
    m = MultiTaskManager(async_mode=True, **kw)
    m.submit(TaskSpec("t", "gsm8k", group_size=2, num_groups=2,
                      target_steps=100))
    m.admit("t")
    return m


# -- episode-queue assembly + micro-batch threshold -----------------------

def test_episode_groups_assemble_in_submit_order():
    m = _mgr(max_staleness=1)
    # rows of group (1, 0) arrive out of submission order (eviction order)
    assert m.enqueue_episode("t", 0, (1, 0), _ep(0, 7))
    assert m.partial_rows("t") == 1 and m.ready_rows("t") == 0
    assert m.enqueue_episode("t", 0, (1, 0), _ep(0, 3))
    assert m.partial_rows("t") == 0 and m.ready_rows("t") == 2
    # a second group completes -> threshold (full round = 4 rows) met
    assert m.pop_episodes() is None            # only half a round ready
    m.enqueue_episode("t", 0, (1, 1), _ep(0, 4))
    m.enqueue_episode("t", 0, (1, 1), _ep(0, 9))
    tid, groups = m.pop_episodes()
    assert tid == "t" and len(groups) == 2
    # within each published group the rows were restored to submit order
    assert [r.submit_index for r in groups[0].rows] == [3, 7]
    assert [r.submit_index for r in groups[1].rows] == [4, 9]


def test_train_threshold_rounds_up_to_complete_groups():
    spec = TaskSpec("t", "gsm8k", group_size=4, num_groups=3)
    assert MultiTaskManager(min_train_rows=0).train_threshold(spec) == 12
    assert MultiTaskManager(min_train_rows=1).train_threshold(spec) == 4
    assert MultiTaskManager(min_train_rows=4).train_threshold(spec) == 4
    assert MultiTaskManager(min_train_rows=5).train_threshold(spec) == 8


def test_stale_episode_dropped_at_enqueue_with_buffered_siblings():
    m = _mgr(max_staleness=0)
    m.enqueue_episode("t", 0, (1, 0), _ep(0, 0))
    # trainer advances past the window while the sibling decodes
    m.tasks["t"].version = 1
    # the late sibling AND its buffered partner are dropped (the group can
    # never complete), counted, never published
    assert m.enqueue_episode("t", 0, (1, 0), _ep(0, 1)) is False
    assert m.partial_rows("t") == 0 and m.ready_rows("t") == 0
    d = m.drop_counters()
    assert d["stale_rows_dropped"] == 2
    assert d["stale_groups_dropped"] == 1
    assert m.pop_episodes() is None


def test_stale_ready_group_pruned_at_pop_time():
    m = _mgr(max_staleness=0, min_train_rows=1)
    for i in range(2):
        m.enqueue_episode("t", 0, (1, 0), _ep(0, i))
    assert m.ready_rows("t") == 2
    m.tasks["t"].version = 1           # committed elsewhere: group now stale
    assert m.pop_episodes() is None    # drop-or-train decided at pop too
    assert m.ready_rows("t") == 0
    assert m.drop_counters()["stale_rows_dropped"] == 2


def test_within_window_episodes_train_and_commit():
    m = _mgr(max_staleness=1, min_train_rows=1)
    for i in range(2):
        m.enqueue_episode("t", 0, (1, 0), _ep(0, i))
    m.tasks["t"].version = 1           # lag 1 <= max_staleness: admissible
    tid, groups = m.pop_episodes()
    assert tid == "t" and sum(len(g.rows) for g in groups) == 2
    m.commit("t", None, None, trained_version=0)
    assert m.version_of("t") == 2
    assert m.drop_counters()["stale_rows_dropped"] == 0


def test_finished_task_purges_queues_and_counts_tail():
    m = MultiTaskManager(async_mode=True, max_staleness=2, min_train_rows=1)
    m.submit(TaskSpec("t", "gsm8k", group_size=2, num_groups=2,
                      target_steps=1))
    m.admit("t")
    for i in range(2):
        m.enqueue_episode("t", 0, (1, 0), _ep(0, i))   # ready group
    m.enqueue_episode("t", 0, (1, 1), _ep(0, 2))       # partial group
    tid, groups = m.pop_episodes()
    m.commit("t", None, None, 0)                       # target hit: finished
    assert m.tasks["t"].status == "finished"
    # nothing may leak for a finished tenant: ready + partial both purged
    assert m.ready_rows("t") == 0 and m.partial_rows("t") == 0
    assert m.drop_counters()["discarded_tail_rows"] == 1
    # and late-arriving rows are discarded+counted, never buffered
    assert m.enqueue_episode("t", 0, (1, 1), _ep(0, 3)) is False
    assert m.drop_counters()["discarded_tail_rows"] == 2


def test_async_issue_budget_bounded_by_staleness_window():
    m = _mgr(max_staleness=1)
    assert m.next_policy("t") is not None      # round 1 under v0
    assert m.next_policy("t") is not None      # round 2 (window = 2)
    assert m.next_policy("t") is None          # budget spent
    for g in range(2):
        for i in range(2):
            m.enqueue_episode("t", 0, (1, g), _ep(0, g * 2 + i))
    m.pop_episodes()
    m.commit("t", None, None, 0)               # resets the round budget
    assert m.next_policy("t") is not None


# -- pop deadline semantics (the spurious-wake bug) -----------------------

def test_pop_batch_deadline_survives_unrelated_notify():
    """A wake-up from an unrelated notify_all (commit/submit/admit of some
    other tenant) must NOT truncate pop_batch's deadline: the old single
    `wait(timeout)` returned None at the first spurious wake; the predicate
    loop re-waits with the remaining time."""
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k"))
    m.admit("t")
    m.next_policy("t")

    def wake_then_feed():
        time.sleep(0.05)
        with m._cv:                   # unrelated wake (e.g. another
            m._cv.notify_all()        # tenant's submit/commit)
        time.sleep(0.15)
        m.enqueue(_tb("t", 0))

    t = threading.Thread(target=wake_then_feed)
    t.start()
    t0 = time.monotonic()
    tb = m.pop_batch(timeout=5.0)
    t.join()
    assert tb is not None, "spurious wake truncated the pop deadline"
    assert time.monotonic() - t0 < 4.0         # woke on the real enqueue


def test_pop_episodes_deadline_survives_unrelated_notify():
    m = _mgr(max_staleness=1, min_train_rows=1)

    def wake_then_feed():
        time.sleep(0.05)
        with m._cv:
            m._cv.notify_all()
        time.sleep(0.15)
        for i in range(2):
            m.enqueue_episode("t", 0, (1, 0), _ep(0, i))

    t = threading.Thread(target=wake_then_feed)
    t.start()
    item = m.pop_episodes(timeout=5.0)
    t.join()
    assert item is not None, "spurious wake truncated the pop deadline"


# -- in-flight train-work recovery (trainer crash between pop and commit) --

def test_recover_inflight_restores_popped_batch_at_queue_head():
    m = MultiTaskManager()
    m.submit(TaskSpec("t", "gsm8k"))
    m.admit("t")
    m.next_policy("t")
    m.enqueue(_tb("t", 0))
    first = m.pop_batch()
    assert m.pop_batch() is None               # queue drained
    # trainer dies here; the restarted loop recovers before consuming
    assert m.recover_inflight() == 1
    again = m.pop_batch()
    assert again is first                      # same batch, at the head
    m.commit("t", None, None, 0)
    assert m.recover_inflight() == 0           # commit retired the tracking


def test_recover_inflight_restores_popped_episode_groups():
    m = _mgr(max_staleness=1, min_train_rows=1)
    for g in range(2):
        for i in range(2):
            m.enqueue_episode("t", 0, (1, g), _ep(0, g * 2 + i))
    tid, groups = m.pop_episodes()
    assert m.ready_rows("t") == 2              # second group still queued
    assert m.recover_inflight() == 1
    assert m.ready_rows("t") == 4              # popped group back at head
    tid2, groups2 = m.pop_episodes()
    assert groups2[0].seq == groups[0].seq     # FIFO order preserved


# -- runtime-level properties (real JAX rollout + GRPO) -------------------

def _tiny_runtime(fam, seed, async_train, max_staleness=0, min_train_rows=0,
                  failure=None, target_steps=2, tenants=2):
    import jax
    from conftest import tiny_lm
    from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
    from repro.models import init_params
    if fam not in _PARAMS:
        c = tiny_lm(FAMILIES[fam])
        _PARAMS[fam] = (c, init_params(jax.random.PRNGKey(0), c))
    cfg, params = _PARAMS[fam]
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(
        policy="marlaas", max_len=48, max_slots=4, seed=seed,
        async_train=async_train, max_staleness=max_staleness,
        min_train_rows=min_train_rows), failure=failure)
    for i in range(tenants):
        rt.submit_task(TaskSpec(f"t{i}", "gsm8k", group_size=2, num_groups=1,
                                max_new_tokens=4 + i, target_steps=target_steps))
    return rt


_PARAMS = {}


def _check_staleness0_parity(fam, seed):
    """With max_staleness=0 the event-driven trainer reduces token-for-token
    to the round-synchronous baseline — same episode order, same micro-batch
    packing, importance correction disabled — so final adapters and reward
    histories are BIT-identical."""
    import jax
    rts = {}
    for mode in (False, True):
        rt = _tiny_runtime(fam, seed, async_train=mode)
        rt.run(timeout_s=300)
        assert rt.mgr.all_done()
        rts[mode] = rt
    sync, asyn = rts[False], rts[True]
    # no drop-or-train decision may have fired at staleness 0
    assert all(v == 0 for v in asyn.mgr.drop_counters().values())
    for tid, st_sync in sync.mgr.task_items():
        st_async = asyn.mgr.state(tid)
        assert st_async.version == st_sync.version
        assert st_async.reward_history == st_sync.reward_history
        for a, b in zip(jax.tree.leaves(st_sync.adapters),
                        jax.tree.leaves(st_async.adapters)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_async_staleness0_bitwise_matches_sync_baseline(fam):
    """Fixed-seed parity across cache families (always runs, even where
    hypothesis is unavailable)."""
    _check_staleness0_parity(fam, seed=5)


@pytest.mark.slow
def test_async_staleness0_parity_property():
    """Hypothesis widening of the same property: ANY seed preserves the
    bit-identity (per-request RNG, episode order and packing all derive
    from submission order, which staleness-0 gating makes deterministic)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2 ** 16))
    @settings(max_examples=2, deadline=None)
    def check(seed):
        _check_staleness0_parity("attention", seed)

    check()


@pytest.mark.slow
@pytest.mark.parametrize("async_train", [False, True])
def test_precommit_crash_restart_recovers_popped_work(async_train):
    """A trainer crash BETWEEN pop and commit used to drop the popped batch
    silently: the rollout side had already spent its issue budget for that
    version, so the tenant deadlocked after restart. The in-flight tracking
    + recover_inflight on trainer re-entry makes the in-memory restart
    finish every task."""
    from repro.core.runtime import FailureInjector
    rt = _tiny_runtime("attention", seed=11, async_train=async_train,
                       max_staleness=1 if async_train else 0,
                       failure=FailureInjector(fail_after_commits=2,
                                               fail_point="pre_commit"),
                       target_steps=3, tenants=1)
    with pytest.raises(RuntimeError, match="pre-commit"):
        rt.run(timeout_s=300)
    # the popped-but-uncommitted work is tracked, not lost
    assert len(rt.mgr._inflight_train) == 1
    rt.error = None
    rt._stop.clear()                    # injector is one-shot: restart runs
    rt.run(timeout_s=300)
    assert rt.mgr.all_done()
    assert rt.rec.counters.get("train_work_recovered", 0) >= 1
    for tid, st in rt.mgr.task_items():
        assert st.steps_done == st.spec.target_steps


@pytest.mark.slow
def test_async_pipelined_run_clean_drain_accounting():
    """Pipelined run (max_staleness=2, sub-round micro-batches): on a clean
    all-done exit the rollout loop's drain invariants hold (no orphaned
    completions, inflight counters at zero — asserted inside the loop) and
    every completed row is accounted exactly once: trained, dropped stale,
    or discarded as a finished task's tail."""
    rt = _tiny_runtime("attention", seed=23, async_train=True,
                       max_staleness=2, min_train_rows=1, target_steps=3,
                       tenants=3)
    rt.run(timeout_s=300)               # raises on any drain-invariant trip
    assert rt.mgr.all_done()
    assert rt.mgr.inflight_rows() == {}
    assert rt.mgr.ready_rows() == 0 and rt.mgr.partial_rows() == 0
    assert not rt.mgr._inflight_train
    d = rt.mgr.drop_counters()
    completed = sum(st.rollout_rows_total for _, st in rt.mgr.task_items())
    assert completed == (rt._rows_trained + d["stale_rows_dropped"]
                         + d["discarded_tail_rows"]), (
        f"row accounting leak: {completed} completed vs "
        f"{rt._rows_trained} trained + {d}")
    # the trainer never sat idle while a full micro-batch was ready
    stats = rt.rec.trainer_idle_stats()
    assert stats["trainer_idle_frac"] <= 0.5
