"""Static-analysis suite (ISSUE 6): per-rule known-bad/known-good fixtures,
noqa suppression, the baseline gate, the src/ self-check, the CLI exit
codes, regression tests for the concurrency fixes the analyzer surfaced
(LengthPredictor, MetricsRecorder, runtime stop hardening), and the two
runtime validators — LockOrderRecorder cross-checked against the static
lock graph over a live continuous-engine workload, and RecompileSentinel's
zero-steady-state-recompile criterion."""
import json
import os
import random
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from conftest import tiny_lm
from repro.analysis import (LockOrderRecorder, RecompileSentinel,
                            analyze_paths, diff_against_baseline,
                            load_baseline, write_baseline)
from repro.core.manager import MultiTaskManager, TaskSpec
from repro.core.metrics import MetricsRecorder
from repro.core.runtime import join_or_raise
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import ContinuousRolloutEngine, RolloutRequest
from repro.rollout.scheduler import LengthPredictor

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def _findings(tmp_path, code, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(code))
    found, _ = analyze_paths([str(p)])
    return found


def _rules(found):
    return sorted({f.rule for f in found})


# -- RA1xx: lock discipline ----------------------------------------------

LOCK_CYCLE_BAD = """
    import threading

    class C:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def m1(self):
            with self._a:
                with self._b:
                    pass

        def m2(self):
            with self._b:
                with self._a:
                    pass
"""

LOCK_CYCLE_GOOD = """
    import threading

    class C:
        def __init__(self):
            self._a = threading.Lock()
            self._b = threading.Lock()

        def m1(self):
            with self._a:
                with self._b:
                    pass

        def m2(self):
            with self._a:
                with self._b:
                    pass
"""


def test_ra101_lock_order_cycle(tmp_path):
    found = _findings(tmp_path, LOCK_CYCLE_BAD)
    assert "RA101" in _rules(found)


def test_ra101_consistent_order_clean(tmp_path):
    assert "RA101" not in _rules(_findings(tmp_path, LOCK_CYCLE_GOOD))


def test_ra101_self_acquire_plain_lock_deadlock(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self._l = threading.Lock()

            def outer(self):
                with self._l:
                    self.inner()

            def inner(self):
                with self._l:
                    pass
    """)
    assert "RA101" in _rules(found)


def test_ra101_rlock_reentry_clean(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self._l = threading.RLock()

            def outer(self):
                with self._l:
                    self.inner()

            def inner(self):
                with self._l:
                    pass
    """)
    assert "RA101" not in _rules(found)


GUARDED_BAD = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()   # guards: _items
            self._items = []

        def covered(self):
            with self._lock:
                return len(self._items)

        def racy(self):
            return self._items.pop()
"""


def test_ra102_guarded_attr_outside_lock(tmp_path):
    found = [f for f in _findings(tmp_path, GUARDED_BAD)
             if f.rule == "RA102"]
    assert len(found) == 1
    assert "_items" in found[0].message


def test_ra102_covered_access_clean(tmp_path):
    good = GUARDED_BAD.replace(
        "return self._items.pop()",
        "with self._lock:\n            return self._items.pop()")
    assert "RA102" not in _rules(_findings(tmp_path, good))


def test_ra102_init_exempt(tmp_path):
    # the snippet's __init__ assigns self._items without the lock held —
    # construction is single-threaded, so only `racy` may fire
    found = [f for f in _findings(tmp_path, GUARDED_BAD)
             if f.rule == "RA102"]
    assert len(found) == 1
    assert "pop" in textwrap.dedent(GUARDED_BAD).splitlines()[found[0].line - 1]


BLOCKING_BAD = """
    import threading
    import time

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def racy(self, fut):
            with self._lock:
                time.sleep(0.1)
                return fut.result()

        def fine(self, fut):
            with self._lock:
                return fut.result(timeout=1.0)
"""


def test_ra103_blocking_call_under_lock(tmp_path):
    found = [f for f in _findings(tmp_path, BLOCKING_BAD)
             if f.rule == "RA103"]
    msgs = " ".join(f.message for f in found)
    assert "time.sleep" in msgs and "result" in msgs
    assert len(found) == 2          # sleep + unbounded .result(); not fine()


def test_ra103_bounded_wait_clean(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self._cond = threading.Condition()

            def fine(self):
                with self._cond:
                    self._cond.wait(timeout=0.05)
    """)
    assert "RA103" not in _rules(found)


def test_ra103_unbounded_condition_wait(tmp_path):
    found = _findings(tmp_path, """
        import threading

        class C:
            def __init__(self):
                self._cond = threading.Condition()

            def racy(self):
                with self._cond:
                    self._cond.wait()
    """)
    assert "RA103" in _rules(found)


# -- RA105: metrics phase-literal discipline (ISSUE 9 satellite) ---------

PHASE_BAD_TYPO = """
    class Runtime:
        def hook(self, t0, t1):
            self.rec.record("rollout", "decoed", "tid", t0, t1)
"""

PHASE_BAD_VARIABLE = """
    def hook(rec, phase, t0, t1):
        rec.record("rollout", phase, "tid", t0, t1)
"""

PHASE_GOOD = """
    class Runtime:
        def hook(self, rec, t0, t1):
            rec.record("rollout", "prefill", "tid", t0, t1)
            self.rec.record("train", "train", "tid", t0, t1)
            rec.record("env", "env", "tid", t0, t1, 0)
"""


def test_ra105_unknown_phase_literal(tmp_path):
    found = _findings(tmp_path, PHASE_BAD_TYPO)
    assert "RA105" in _rules(found)
    assert any("decoed" in f.message for f in found)


def test_ra105_variable_phase(tmp_path):
    assert "RA105" in _rules(_findings(tmp_path, PHASE_BAD_VARIABLE))


def test_ra105_registered_literals_clean(tmp_path):
    assert "RA105" not in _rules(_findings(tmp_path, PHASE_GOOD))


def test_ra105_noqa_for_guarded_variable(tmp_path):
    code = PHASE_BAD_VARIABLE.replace(
        'rec.record("rollout", phase, "tid", t0, t1)',
        'rec.record("rollout", phase, "tid", t0, t1)  # noqa: RA105')
    assert "RA105" not in _rules(_findings(tmp_path, code))


# -- RA2xx: JAX trace hygiene --------------------------------------------

def test_ra201_branch_on_tracer(tmp_path):
    found = _findings(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert "RA201" in _rules(found)


def test_ra201_static_arg_and_where_clean(tmp_path):
    found = _findings(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def g(x):
            return jnp.where(x > 0, x, -x)

        def h(n, x):
            if n > 0:
                return x
            return -x

        h_jit = jax.jit(h, static_argnums=(0,))

        @jax.jit
        def k(x):
            if x.ndim > 1:          # shape metadata is concrete under trace
                return x.sum()
            return x
    """)
    assert "RA201" not in _rules(found)


def test_ra202_host_sync_on_tracer(tmp_path):
    found = _findings(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            y = float(x)
            z = np.asarray(x)
            return y + z.sum()
    """)
    assert [f.rule for f in found].count("RA202") >= 2


def test_ra202_device_side_cast_clean(tmp_path):
    found = _findings(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = x.astype(jnp.float32)
            n = float(x.shape[0])   # shape access: concrete, not a sync
            return y * n
    """)
    assert "RA202" not in _rules(found)


def test_ra203_captured_state_mutation(tmp_path):
    found = _findings(tmp_path, """
        import jax

        class M:
            def __init__(self):
                self.count = 0

            def make(self):
                @jax.jit
                def step(x):
                    self.count += 1     # silently frozen after trace 1
                    return x
                return step
    """)
    assert "RA203" in _rules(found)


def test_ra203_pure_closure_clean(tmp_path):
    found = _findings(tmp_path, """
        import jax

        class M:
            def __init__(self):
                self.scale = 2.0

            def make(self):
                scale = self.scale      # read-only capture is fine

                @jax.jit
                def step(x):
                    return x * scale
                return step
    """)
    assert "RA203" not in _rules(found)


def test_ra204_unbucketed_len_recompile_hazard(tmp_path):
    found = _findings(tmp_path, """
        import jax
        import numpy as np

        step = jax.jit(lambda t: t * 2)

        def run(reqs):
            n = len(reqs)
            toks = np.zeros((n, 8), np.int32)
            return step(toks)
    """)
    assert "RA204" in _rules(found)


def test_ra204_bucketed_len_clean(tmp_path):
    found = _findings(tmp_path, """
        import jax
        import numpy as np

        step = jax.jit(lambda t: t * 2)

        def _bucket(n):
            b = 8
            while b < n:
                b *= 2
            return b

        def run(reqs):
            n = _bucket(len(reqs))
            toks = np.zeros((n, 8), np.int32)
            return step(toks)
    """)
    assert "RA204" not in _rules(found)


# -- RA3xx: Pallas kernel checks -----------------------------------------

def _pallas(body: str) -> str:
    return ("import jax\nfrom jax.experimental import pallas as pl\n"
            + textwrap.dedent(body))


def test_ra301_index_map_arity(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((32, 512), x.dtype),
            )(x)
    """))
    assert "RA301" in _rules(found)


def test_ra301_matching_arity_clean(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((32, 512), x.dtype),
            )(x)
    """))
    assert "RA301" not in _rules(found)


def test_ra302_index_map_rank(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i,))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
            )(x)
    """))
    assert "RA302" in _rules(found)


def test_ra302_ref_literal_oob(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref, o_ref):
            o_ref[0] = x_ref[5]     # block dim 0 is 2: rows 0..1 only

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((2, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((2, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((8, 128), x.dtype),
            )(x)
    """))
    assert "RA302" in _rules(found)


def test_ra302_in_bounds_clean(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref, o_ref):
            o_ref[0] = x_ref[1]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((2, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((2, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((8, 128), x.dtype),
            )(x)
    """))
    assert "RA302" not in _rules(found)


def test_ra303_kernel_arity(tmp_path):
    found = _findings(tmp_path, _pallas("""
        def kernel(x_ref):              # missing the output ref
            x_ref[...] = x_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
            )(x)
    """))
    assert "RA303" in _rules(found)


def test_ra303_scalar_prefetch_order(tmp_path):
    code = _pallas("""
        import jax.numpy as jnp
        from jax.experimental.pallas import tpu as pltpu

        def kern(idx_ref, x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, idx):
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(4,),
                in_specs=[pl.BlockSpec((8, 128), lambda i, idx: (i, 0))],
                out_specs=pl.BlockSpec((8, 128), lambda i, idx: (i, 0)),
            )
            return pl.pallas_call(
                kern, grid_spec=grid_spec,
                out_shape=jax.ShapeDtypeStruct((32, 128), x.dtype),
            )({ARGS})
    """)
    bad = _findings(tmp_path, code.replace(
        "{ARGS}", "x, idx.astype(jnp.int32)"), name="bad.py")
    good = _findings(tmp_path, code.replace(
        "{ARGS}", "idx.astype(jnp.int32), x"), name="good.py")
    assert "RA303" in _rules(bad)
    assert "RA303" not in _rules(good)


# -- suppression + baseline gate -----------------------------------------

def test_noqa_suppresses_matching_rule(tmp_path):
    code = GUARDED_BAD.replace("return self._items.pop()",
                               "return self._items.pop()  # noqa: RA102")
    assert "RA102" not in _rules(_findings(tmp_path, code))


def test_noqa_other_rule_does_not_suppress(tmp_path):
    code = GUARDED_BAD.replace("return self._items.pop()",
                               "return self._items.pop()  # noqa: RA103")
    assert "RA102" in _rules(_findings(tmp_path, code))


def test_baseline_roundtrip_and_diff(tmp_path):
    found = _findings(tmp_path, BLOCKING_BAD)
    base_path = tmp_path / "baseline.json"
    write_baseline(found, base_path)
    base = load_baseline(base_path)
    assert diff_against_baseline(found, base) == []
    # a NEW violation of an already-baselined rule still fails the gate
    extra = _findings(tmp_path, BLOCKING_BAD + """
        def also_racy(c, fut):
            with c._lock:
                fut.result()
    """)
    new = diff_against_baseline(extra, base)
    assert len(new) == 1 and new[0].rule == "RA103"


def test_baseline_is_line_number_free(tmp_path):
    # shifting code down must not invalidate the baseline
    found = _findings(tmp_path, BLOCKING_BAD)
    base_path = tmp_path / "baseline.json"
    write_baseline(found, base_path)
    shifted = _findings(tmp_path, "\n\n# comment\n" + BLOCKING_BAD)
    assert diff_against_baseline(shifted, load_baseline(base_path)) == []


def test_src_tree_matches_committed_baseline():
    """The self-check: the analyzer over src/ with the committed baseline
    yields zero new findings (exactly what CI gates on)."""
    found, model = analyze_paths([str(SRC)])
    new = diff_against_baseline(found, load_baseline())
    assert new == [], "\n".join(f.format() for f in new)
    # the known lock inventory: the model must keep discovering these
    displays = {d.display for d in model.locks.values()}
    for expected in ("MultiTaskManager._lock", "MetricsRecorder._lock",
                     "ContinuousRolloutEngine._stage_lock",
                     "EnvStage._cond", "LengthPredictor._lock"):
        assert expected in displays, f"lock discovery lost {expected}"


def test_engine_queue_reads_are_lock_covered():
    """Regression: `_refill_free_slots` once read `self._sched` off the
    engine thread without `_stage_lock` — the analyzer flagged it (RA102)
    and the fix moved the read under the lock. Keep it that way."""
    found, _ = analyze_paths([str(SRC / "repro" / "rollout" / "engine.py")])
    racy = [f for f in found if f.rule == "RA102" and "_sched" in f.message]
    assert racy == [], "\n".join(f.format() for f in racy)


def test_cli_gate_exit_codes(tmp_path):
    bad = tmp_path / "code.py"
    bad.write_text(textwrap.dedent(BLOCKING_BAD))
    base = tmp_path / "baseline.json"
    env = dict(os.environ, PYTHONPATH=str(SRC))

    def run(*argv):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *argv],
            env=env, capture_output=True, text=True, cwd=str(tmp_path))

    r = run("--check", "--baseline", str(base), str(tmp_path))
    assert r.returncode == 1 and "new finding(s)" in r.stdout
    r = run("--write-baseline", "--baseline", str(base), str(tmp_path))
    assert r.returncode == 0 and base.exists()
    r = run("--check", "--baseline", str(base), "--report",
            str(tmp_path / "report.json"), str(tmp_path))
    assert r.returncode == 0, r.stdout + r.stderr
    report = json.loads((tmp_path / "report.json").read_text())
    assert {e["rule"] for e in report["findings"]} == {"RA103"}


# -- regression: the concurrency fixes the analyzer surfaced --------------

def test_length_predictor_thread_safety():
    pred = LengthPredictor(alpha=0.5)
    errs = []

    def hammer(tid):
        try:
            for i in range(2000):
                pred.observe(f"t{tid % 2}", 4 + (i % 9))
        except BaseException as e:      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        for tid in ("t0", "t1"):
            p = pred.predict(tid, 16)
            assert 1.0 <= p <= 16.0
    for t in threads:
        t.join()
    assert not errs
    # every observation was in [4, 12] so the EMA must be too
    for tid in ("t0", "t1"):
        assert 4.0 <= pred.predict(tid, 100) <= 12.0


def test_metrics_recorder_concurrent_samples():
    rec = MetricsRecorder({"rollout": 2})
    errs = []

    def writer(k):
        try:
            for i in range(1500):
                t = i * 1e-4
                rec.record_slot_sample(t, k % 3, 2)
                rec.record_queue_sample(t, i % 5, i % 3)
                rec.record_env_sample(t, i % 2, i % 2)
                rec.record_page_sample(t, i % 7, 8, 0.1)
                rec.record("rollout", "decode", f"t{k}", t, t + 1e-4)
                rec.incr("evictions")
        except BaseException as e:      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    # readers run against live writers: none of these may crash or return
    # garbage mid-append
    while any(t.is_alive() for t in threads):
        for stat in (rec.slot_utilization_pct, rec.env_wait_seconds,
                     rec.queue_depth_stats, rec.page_pool_stats,
                     rec.idle_pct):
            v = stat()
            assert v is not None
    for t in threads:
        t.join()
    assert not errs
    assert rec.counters["evictions"] == 4 * 1500
    assert len(rec.slot_samples) == 4 * 1500


def test_join_or_raise_flags_wedged_thread():
    release = threading.Event()
    t = threading.Thread(target=release.wait, name="wedged-worker",
                         daemon=True)
    t.start()
    with pytest.raises(RuntimeError, match="wedged-worker"):
        join_or_raise([t], timeout_s=0.2)
    release.set()
    t.join(timeout=5)


def test_join_or_raise_clean_exit():
    t = threading.Thread(target=lambda: None)
    t.start()
    join_or_raise([t], timeout_s=5.0)   # no raise


# -- runtime validators over a live engine workload -----------------------

def _requests(n=6):
    env = make_env("gsm8k")
    rng = random.Random(3)
    reqs = []
    for i in range(n):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=6, seed=i))
    return reqs


def _drive(eng, reqs, max_iters=5000):
    comps = {}
    for r in reqs:
        eng.submit(r)
    deadline = time.monotonic() + 120
    it = 0
    while not eng.idle() and it < max_iters:
        progressed = eng.step()
        it += 1
        for c in eng.drain_completions():
            comps[c.submit_index] = c
        if not progressed:
            if time.monotonic() > deadline:     # pragma: no cover
                break
            time.sleep(0.0005)
    assert len(comps) == len(reqs)
    return comps


@pytest.fixture(scope="module")
def live_run():
    """One continuous-engine workload with every lock-owning subsystem
    (scheduler, env stage, disaggregated prefill, manager, metrics)
    created under the LockOrderRecorder and then driven to completion."""
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    with LockOrderRecorder() as rec:
        eng = ContinuousRolloutEngine(cfg, params, max_slots=2,
                                      max_adapters=2, max_len=96, seed=0,
                                      env_stage=True, disagg_prefill=True)
        mgr = MultiTaskManager()
        metrics = MetricsRecorder({"rollout": 1})
    for i, tree in enumerate(trees):
        eng.set_adapters(i, tree)
    reqs = _requests()
    comps = _drive(eng, reqs)
    # exercise the manager's RLock + Condition through the proxy protocol
    # (pop_batch's timed wait goes through _release_save/_acquire_restore)
    mgr.submit(TaskSpec("t0", "gsm8k"))
    mgr.admit("t0")
    assert mgr.next_policy("t0") == (0, None)
    assert mgr.pop_batch(timeout=0.02) is None
    metrics.record("rollout", "decode", "t0", 0.0, 1.0)
    metrics.incr("smoke")
    return rec, eng, reqs, comps


def test_lock_recorder_validates_static_model(live_run):
    rec, *_ = live_run
    _, model = analyze_paths([str(SRC)])
    problems = rec.check_against(model)
    assert problems == [], "\n".join(problems)
    # the recorder saw the locks the static model predicts (creation
    # sites are the shared key between the two worlds)
    by_display = {d.display: d.lock_id for d in model.locks.values()}
    for disp in ("ContinuousRolloutEngine._stage_lock",
                 "LengthPredictor._lock", "MultiTaskManager._lock"):
        assert by_display[disp] in rec.sites, f"{disp} never recorded"
    # the one statically-predicted nesting actually happened: the SRPT
    # pop ranks entries (predictor lock) under the engine stage lock
    edge = (by_display["ContinuousRolloutEngine._stage_lock"],
            by_display["LengthPredictor._lock"])
    assert edge in rec.edges


def test_lock_recorder_flags_unknown_and_inverted():
    class _FakeModel:
        def sites(self):
            return {"a", "b"}

        def edge_pairs(self):
            return {("a", "b")}

    rec = LockOrderRecorder()
    rec.sites = {"a", "b", "mystery"}
    rec.edges = {("b", "a"): 1, ("a", "mystery"): 1}
    problems = rec.check_against(_FakeModel())
    assert any("unknown to the static model" in p for p in problems)
    assert any("lock-order inversion" in p for p in problems)
    # consistent observations are clean
    rec2 = LockOrderRecorder()
    rec2.edges = {("a", "b"): 3}
    assert rec2.check_against(_FakeModel()) == []


def test_recompile_sentinel_counts_misses():
    f = jax.jit(lambda x: x * 2)
    f(jnp.zeros((4,), jnp.float32))
    sent = RecompileSentinel()
    assert sent.track("f", f)
    assert sent.new_compiles() == {}
    f(jnp.zeros((8,), jnp.float32))         # new shape -> retrace
    assert sent.new_compiles() == {"f": 1}
    sent.mark()
    assert sent.new_compiles() == {}
    f(jnp.zeros((4,), jnp.float32))         # cached -> still clean
    assert sent.new_compiles() == {}


def test_zero_steady_state_decode_recompiles(live_run):
    """Acceptance criterion: after one full warmup workload, re-running
    the identical request mix triggers ZERO retraces across every jitted
    callable the engine owns."""
    _, eng, reqs, _ = live_run
    sent = RecompileSentinel()
    tracked = sent.track_engine(eng)
    assert "_step_fn" in tracked
    sent.mark()
    _drive(eng, reqs)
    assert sent.new_compiles() == {}, sent.cache_sizes()
