"""Paged KV-cache block pool + snapshot/restore resume (ISSUE 5).

1. Allocator property (hypothesis): any alloc/retain/release interleaving
   preserves the pool invariants — no page owned by two live rows unless
   explicitly retained, free-list conservation, no leaks.
2. Restore-resume parity: the paged engine with snapshot/restore produces
   token-for-token identical output to the dense-cache engine and the
   one-shot oracle, across attention/SSM/hybrid, preempt-at-any-step
   (hypothesis) and through park/resume agentic turns (both fill paths).
3. Snapshot dropped under memory pressure falls back to token replay with
   identical output.
4. Pool exhaustion mid-decode finishes rows via cache-capacity eviction
   (never a crash, never a leak).
5. Cooperative tool-call cancellation frees workers immediately.
6. Page-granular admission packs more rows than max_len reservation.
"""
import random
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # property tests skip without hypothesis; the rest still run
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
requires_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                         reason="hypothesis not installed")

from conftest import tiny_lm
from repro.data import tokenizer as tok
from repro.envs.base import CancelToken, ToolSession
from repro.envs.tasks import make_env
from repro.lora.adapters import init_lora
from repro.models import init_params
from repro.rollout.engine import (ContinuousRolloutEngine, RolloutEngine,
                                  RolloutRequest, _submit_tool_call)
from repro.rollout.env_stage import EnvStage
from repro.rollout.kvcache import KVSnapshot, PagePool, SnapshotStore, pages_for

FAMILIES = {"attention": "granite-3-2b", "ssm": "mamba2-780m",
            "hybrid": "zamba2-1.2b"}
_CACHE = {}


# ===========================================================================
# 1. allocator invariants
# ===========================================================================

@requires_hypothesis
def test_page_pool_property():
    """Model-based allocator check: a host-side mirror of owner->pages
    tracks every alloc/retain/release; after every op the pool invariants
    hold and no page is owned by two live owners (unless one retained it,
    which models snapshot sharing)."""

    @given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "release",
                                                   "retain"]),
                                  st.integers(0, 5)),
                        min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def check(ops):
        pool = PagePool(n_pages=12, page_size=8)
        owners = {}             # owner id -> list of pages (rc 1 each)
        shared = []             # pages given an extra rc via retain
        next_id = 0
        for kind, n in ops:
            if kind == "alloc":
                pages = pool.alloc(n)
                if pages is not None:
                    # freshly allocated pages are exclusively owned
                    live = {p for ps in owners.values() for p in ps}
                    assert not (set(pages) & live), "page aliased"
                    assert len(pages) == n      # all-or-nothing
                    owners[next_id] = pages
                    next_id += 1
            elif kind == "release" and owners:
                key = sorted(owners)[n % len(owners)]
                pool.release(owners.pop(key))
            elif kind == "retain" and owners:
                key = sorted(owners)[n % len(owners)]
                pool.retain(owners[key])
                shared.append(list(owners[key]))
            pool.check_invariants()
        for ps in shared:       # drop the snapshot-style extra refs
            pool.release(ps)
        for ps in owners.values():
            pool.release(ps)
        pool.check_invariants()
        assert pool.used_pages == 0 and pool.free_pages == pool.n_pages

    check()


def test_page_pool_basics():
    pool = PagePool(n_pages=4, page_size=16)
    a = pool.alloc(3)
    assert len(a) == 3 and pool.free_pages == 1
    assert pool.alloc(2) is None            # all-or-nothing
    assert pool.free_pages == 1             # refused alloc left no debris
    pool.retain(a)
    pool.release(a)
    assert pool.used_pages == 3             # still held by the retain
    pool.release(a)
    assert pool.used_pages == 0
    with pytest.raises(ValueError):
        pool.release([a[0]])                # double free
    assert pages_for(0, 16) == 0 and pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1 and pages_for(17, 16) == 2


def test_snapshot_store_budget():
    store = SnapshotStore(budget_bytes=100)
    small = KVSnapshot(pos=4, cur=1, ssm=np.zeros(10, np.float32))  # 40 B
    big = KVSnapshot(pos=4, cur=1, ssm=np.zeros(32, np.float32))    # 128 B
    assert store.try_add(small) and store.bytes_used == 40
    assert not store.try_add(big) and store.drops == 1
    store.remove(small)
    assert store.bytes_used == 0


# ===========================================================================
# 2. restore-resume parity (preempt-at-any-step, all families)
# ===========================================================================

def _family(fam: str):
    """(reqs, one-shot reference, reusable PAGED engine) per family."""
    if fam not in _CACHE:
        cfg = tiny_lm(FAMILIES[fam])
        params = init_params(jax.random.PRNGKey(0), cfg)
        trees = [init_lora(jax.random.PRNGKey(1), cfg),
                 init_lora(jax.random.PRNGKey(2), cfg)]
        env = make_env("gsm8k")
        rng = random.Random(7)
        reqs = []
        for i in range(3):
            prompt, truth = env.sample_prompt(rng)
            reqs.append(RolloutRequest(
                f"t{i % 2}", i % 2, prompt, truth, env,
                max_new_tokens=5 + 2 * i, seed=i))
        ref_eng = RolloutEngine(cfg, params, max_len=64, seed=0)
        ref, _ = ref_eng.generate(reqs, trees)       # uninterrupted oracle
        eng = ContinuousRolloutEngine(cfg, params, max_slots=2,
                                      max_adapters=2, max_len=64, seed=0,
                                      paged_kv=True, kv_page_size=16)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        _CACHE[fam] = (reqs, ref, eng)
    return _CACHE[fam]


def _drive(eng, reqs, preempt_step, victims):
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, preempted, iters = {}, 0, 0
    while not eng.idle() and iters < 400:
        eng.step()
        iters += 1
        if iters == preempt_step:
            for v in victims:
                preempted += eng.preempt_tenant(v)
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
    assert len(comps) == len(reqs), "engine failed to drain"
    eng.check_page_invariants()
    return comps, preempted


@requires_hypothesis
@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_restore_resume_parity_property(fam):
    """Preempting at ANY step and restoring the snapshotted pages+state
    yields bit-identical tokens/logprobs to an uninterrupted one-shot run
    — with ZERO prefill replays (restore mode never re-prefills)."""
    reqs, ref, eng = _family(fam)
    observed = {"n": 0}

    @given(preempt_step=st.integers(1, 14),
           victims=st.sampled_from([("t0",), ("t1",), ("t0", "t1")]))
    @settings(max_examples=8, deadline=None)
    def check(preempt_step, victims):
        comps, preempted = _drive(eng, reqs, preempt_step, victims)
        observed["n"] += preempted
        for i, r in enumerate(ref):
            c = comps[i]
            assert list(c.tokens) == r["tokens"], (
                f"{fam}: token mismatch, preempt@{preempt_step}")
            assert list(c.gen_loss_mask) == r["gen_loss_mask"]
            np.testing.assert_allclose(c.gen_logprobs, r["gen_logprobs"],
                                       atol=1e-5)

    check()
    assert observed["n"] > 0
    assert eng.stats.restores > 0
    # attention rows park DEVICE-RESIDENT under the prefix cache (pure
    # retain, zero host bytes); recurrent state still snapshots to host
    assert (eng.stats.snapshots > 0
            or eng.stats.device_resident_resumes > 0)
    assert eng.stats.replays == 0           # restore NEVER replays
    assert eng.stats.replay_tokens == 0
    # no leak: only radix-index retained prompt pages may remain at idle,
    # the snapshot arena is empty
    held = eng._prefix_idx.held_pages if eng._prefix_idx else 0
    assert eng._pages.used_pages == held
    assert eng._snap_store.bytes_used == 0
    eng.check_page_invariants()


# ===========================================================================
# 2b. agentic park/resume restore across both fill paths
# ===========================================================================

@pytest.fixture
def biased_sampler():
    """Deterministic CALL pattern at fixed per-row counters (the
    bench_env_stage trick), restored after the test."""
    import repro.rollout.engine as eng_mod
    import repro.rollout.prefill as pf_mod
    orig = pf_mod._sample_rows

    def biased(logits, keys, counters, temps):
        s = orig(logits, keys, counters, temps)
        s = jnp.where(s == tok.EOS, 10, s)
        hit = (counters == 1) | (counters == 6)
        return jnp.where(hit, tok.CALL, s)

    pf_mod._sample_rows = biased
    eng_mod._sample_rows = biased
    yield
    pf_mod._sample_rows = orig
    eng_mod._sample_rows = orig


def _run_engine(eng, reqs, preempt_at=()):
    pos_of = {eng.submit(r): i for i, r in enumerate(reqs)}
    comps, it = {}, 0
    deadline = time.monotonic() + 120
    while not eng.idle() and time.monotonic() < deadline:
        progressed = eng.step()
        it += 1
        if it in preempt_at:
            eng.preempt_tenant("t0")
            eng.preempt_tenant("t1")
        for c in eng.drain_completions():
            comps[pos_of[c.submit_index]] = c
        if not progressed:
            time.sleep(0.0005)
    assert len(comps) == len(reqs), "engine failed to drain"
    if getattr(eng, "paged_kv", False):
        eng.check_page_invariants()
    return comps


def _agentic_reqs(hops=2):
    env = make_env("hopsearch", kb_size=8, hops=hops, seed=0)
    env.env_latency_mean = 0.0
    rng = random.Random(7)
    reqs = []
    for i in range(4):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest(f"t{i % 2}", i % 2, prompt, truth, env,
                                   max_new_tokens=10, seed=i))
    return reqs


@pytest.mark.parametrize("fam", ["hybrid", "attention"])
@pytest.mark.parametrize("disagg", [False, True])
def test_park_restore_parity_agentic(fam, disagg, biased_sampler):
    """Multi-turn episodes (park on CALL, resume on response) restore
    token-for-token identically to the dense-cache engine on the SAME
    schedule — fused and disaggregated fill paths, preempt mid-episode
    included (preempt-during-parked rows keep their snapshots)."""
    cfg = tiny_lm(FAMILIES[fam])
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    reqs = _agentic_reqs()

    outs, stats = {}, {}
    for mode in ("dense", "paged"):
        eng = ContinuousRolloutEngine(
            cfg, params, max_slots=2, max_adapters=2, max_len=96, seed=0,
            paged_kv=(mode == "paged"), kv_page_size=16,
            env_stage=True, env_workers=2, disagg_prefill=disagg)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        outs[mode] = _run_engine(eng, reqs, preempt_at=(6, 14))
        stats[mode] = eng.stats
        if mode == "paged":
            assert eng._pages.used_pages == 0       # no leak at idle
            assert eng._snap_store.bytes_used == 0
        eng.shutdown()
    for i in range(len(reqs)):
        d, p = outs["dense"][i], outs["paged"][i]
        assert list(d.tokens) == list(p.tokens), (fam, disagg, i)
        assert list(d.gen_loss_mask) == list(p.gen_loss_mask)
        np.testing.assert_allclose(d.gen_logprobs, p.gen_logprobs,
                                   atol=1e-5)
    assert stats["paged"].parks > 0 and stats["paged"].resumes > 0
    assert stats["paged"].restores > 0
    assert stats["paged"].replay_tokens == 0        # the tentpole claim
    assert stats["dense"].replay_tokens > 0         # baseline recomputes


def test_snapshot_drop_falls_back_to_replay(biased_sampler):
    """snapshot_budget_bytes=1 rejects every snapshot: all resumes fall
    back to token replay, with output identical to restore mode."""
    cfg = tiny_lm("zamba2-1.2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    trees = [init_lora(jax.random.PRNGKey(1), cfg),
             init_lora(jax.random.PRNGKey(2), cfg)]
    reqs = _agentic_reqs()
    outs, stats = {}, {}
    for mode, budget in (("restore", 0), ("dropped", 1)):
        eng = ContinuousRolloutEngine(
            cfg, params, max_slots=2, max_adapters=2, max_len=96, seed=0,
            paged_kv=True, kv_page_size=16, env_stage=True, env_workers=2,
            snapshot_budget_bytes=budget)
        for i, tree in enumerate(trees):
            eng.set_adapters(i, tree)
        outs[mode] = _run_engine(eng, reqs)
        stats[mode] = eng.stats
        eng.shutdown()
    for i in range(len(reqs)):
        a, b = outs["restore"][i], outs["dropped"][i]
        assert list(a.tokens) == list(b.tokens)
        np.testing.assert_allclose(a.gen_logprobs, b.gen_logprobs,
                                   atol=1e-5)
    assert stats["restore"].restores > 0 and stats["restore"].replays == 0
    assert stats["dropped"].restores == 0 and stats["dropped"].replays > 0
    assert stats["dropped"].snapshot_drops > 0


# ===========================================================================
# 4. pool exhaustion mid-decode
# ===========================================================================

def test_pool_exhaustion_finishes_rows():
    """A pool too small for every row's growth finishes rows via
    cache-capacity eviction: every submitted row completes, nothing
    crashes, and the free list is conserved."""
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tree = init_lora(jax.random.PRNGKey(1), cfg)
    env = make_env("gsm8k")
    rng = random.Random(3)
    reqs = []
    for i in range(6):
        prompt, truth = env.sample_prompt(rng)
        reqs.append(RolloutRequest("t0", 0, prompt, truth, env,
                                   max_new_tokens=40, seed=i))
    # 3 pages x 8 tokens for 2 slots: prompts fit (1-2 pages) but growth
    # past the page boundary starves the pool mid-decode
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=64, seed=0, paged_kv=True,
                                  kv_page_size=8, kv_pool_pages=3)
    eng.set_adapters(0, tree)
    comps = _run_engine(eng, reqs)
    assert len(comps) == len(reqs)
    reasons = {c.finish_reason for c in comps.values()}
    assert eng.stats.pool_exhausted > 0 and "capacity" in reasons
    assert eng._pages.used_pages == 0       # everything released
    eng._pages.check_invariants()


def test_row_larger_than_pool_finishes_capacity():
    """A row whose prompt alone exceeds the whole pool can never fit: it
    must finish (capacity), not deadlock the queue."""
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tree = init_lora(jax.random.PRNGKey(1), cfg)
    env = make_env("gsm8k")
    rng = random.Random(3)
    prompt, truth = env.sample_prompt(rng)
    prompt = prompt + [5] * (20 - len(prompt))       # 20 tokens > 2 pages
    eng = ContinuousRolloutEngine(cfg, params, max_slots=2, max_adapters=1,
                                  max_len=64, seed=0, paged_kv=True,
                                  kv_page_size=8, kv_pool_pages=2)
    eng.set_adapters(0, tree)
    comps = _run_engine(eng, [RolloutRequest("t0", 0, prompt, truth, env,
                                             max_new_tokens=4, seed=0)])
    assert comps[0].finish_reason == "capacity"


# ===========================================================================
# 5. cooperative tool-call cancellation
# ===========================================================================

class _SlowSession(ToolSession):
    def __init__(self):
        self.calls = 0

    def call(self, query_ids, cancel=None):
        self.calls += 1
        return [1, 2]


def test_env_stage_cancel_frees_worker_immediately():
    """A timed-out job mid latency-sleep releases its worker NOW: a
    second job completes far sooner than the first job's latency."""
    class _Row:
        session = _SlowSession()
    stage = EnvStage(1)                      # ONE worker: job B must wait
    t0 = time.monotonic()                    # for job A's worker
    stage.submit(_Row(), [1], "a", latency=30.0)
    time.sleep(0.05)                         # let the worker pick A up
    stage.expire(time.monotonic() + 100.0, 1.0)   # time A out -> cancel
    rb = _Row()
    stage.submit(rb, [2], "b", latency=0.0)
    deadline = time.monotonic() + 5.0
    done = []
    while not done and time.monotonic() < deadline:
        done = stage.drain_resolved()
        time.sleep(0.005)
    elapsed = time.monotonic() - t0
    stage.halt()
    assert done and done[0].row is rb
    assert elapsed < 5.0, f"worker stayed pinned for {elapsed:.1f}s"


def test_submit_tool_call_token_interrupts_latency():
    """Cancelling the freeze-in-slot path's token interrupts the latency
    sleep and skips the session call."""
    from concurrent.futures import ThreadPoolExecutor

    class _Req:
        class env:
            @staticmethod
            def sample_env_latency(rng):
                return 30.0
        task_id = "t"

    class _FakeRow:
        req = _Req()
        gen = [1]
        session = _SlowSession()

        def ensure_session(self):
            return self.session

    pool = ThreadPoolExecutor(max_workers=1)
    rng = np.random.RandomState(0)
    t0 = time.monotonic()
    fut, token = _submit_tool_call(_FakeRow(), [1, 2], pool, rng, False)
    time.sleep(0.05)
    token.cancel()
    assert fut.result(timeout=5.0) == []
    assert time.monotonic() - t0 < 5.0
    assert _FakeRow.session.calls == 0       # never reached the session
    pool.shutdown(wait=False)


def test_cancel_token_forwarding_legacy_session():
    """call_session forwards the token to sessions that accept it and
    still works with legacy sessions that don't."""
    from repro.envs.base import call_session

    class Legacy:
        def call(self, q):
            return [7]

    class Modern:
        def __init__(self):
            self.got = None

        def call(self, q, cancel=None):
            self.got = cancel
            return [8]

    tok_ = CancelToken()
    assert call_session(Legacy(), [1], tok_) == [7]
    m = Modern()
    assert call_session(m, [1], tok_) == [8]
    assert m.got is tok_


# ===========================================================================
# 6. page-granular admission
# ===========================================================================

def test_paged_admission_packs_tighter():
    from repro.configs import REGISTRY
    from repro.core.admission import (AdmissionConfig, AdmissionController,
                                      task_state_bytes,
                                      task_state_bytes_paged)
    from repro.core.manager import TaskSpec
    cfg = REGISTRY["granite-3-2b"]
    spec = TaskSpec("t", "gsm8k", group_size=8, num_groups=2,
                    max_new_tokens=512)
    dense = task_state_bytes(cfg, spec, 64)
    cold = task_state_bytes_paged(cfg, spec, 64, page_size=16)
    warm = task_state_bytes_paged(cfg, spec, 64, page_size=16,
                                  expected_new_tokens=48.0)
    # cold (no history) stays pessimistic; warm packs far tighter
    assert abs(cold - dense) / dense < 0.05
    assert warm < 0.3 * dense
    # the controller admits more tasks under the same budget when paged
    budget = 4 * dense
    dense_ctl = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=budget))
    paged_ctl = AdmissionController(cfg, AdmissionConfig(
        memory_budget_bytes=budget, paged=True, page_size=16))
    n_dense = n_paged = 0
    for i in range(64):
        s = TaskSpec(f"d{i}", "gsm8k", group_size=8, num_groups=2,
                     max_new_tokens=512)
        if dense_ctl.try_admit(s, 64):
            n_dense += 1
    for i in range(64):
        s = TaskSpec(f"p{i}", "gsm8k", group_size=8, num_groups=2,
                     max_new_tokens=512)
        if paged_ctl.try_admit(s, 64, expected_new_tokens=48.0):
            n_paged += 1
    assert n_paged >= 1.5 * n_dense
    # actual-bytes readmission re-estimate only ever tightens
    paged_ctl.preempt("p0")
    first = paged_ctl.reestimate_preempted_bytes("p0", 10_000)
    assert first == 10_000
    assert paged_ctl.reestimate_preempted_bytes("p0", 50_000) == 10_000
