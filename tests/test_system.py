"""End-to-end behaviour of the paper's system — Algorithm 1 executed by the
real runtime on this host, with every invariant from §4 checked:

 (1) strict per-task on-policy consistency (each trained batch matches the
     exact version it was generated under — enforced + asserted),
 (2) cross-task phase overlap (rollout intervals of one task overlap train
     intervals of another in the recorded timeline),
 (3) serialized single-task training (train intervals never overlap),
 (4) multi-LoRA cross-task rollout batching (one fused generate served
     multiple tenants).
"""
import jax
import pytest

from conftest import tiny_lm
from repro.core.manager import TaskSpec
from repro.core.runtime import MARLaaSRuntime, RuntimeConfig
from repro.models import init_params

pytestmark = pytest.mark.slow


def test_marlaas_algorithm1_invariants():
    cfg = tiny_lm("granite-3-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rt = MARLaaSRuntime(cfg, params, RuntimeConfig(policy="marlaas",
                                                   max_len=48, seed=11))
    rt.submit_task(TaskSpec("gsm-0", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=4, target_steps=3))
    rt.submit_task(TaskSpec("gsm-1", "gsm8k", group_size=2, num_groups=1,
                            max_new_tokens=4, target_steps=3))
    rt.run(timeout_s=300)
    assert rt.mgr.all_done()

    # (1) on-policy: versions advanced exactly once per step
    for st in rt.mgr.tasks.values():
        assert st.version == 3 and st.steps_done == 3

    ivs = rt.rec.intervals
    trains = sorted([iv for iv in ivs if iv.phase == "train"],
                    key=lambda iv: iv.start)
    rollouts = [iv for iv in ivs if iv.phase == "decode"]
    assert len(trains) == 6 and rollouts

    # (3) training engine is serialized (paper §4.5)
    for a, b in zip(trains, trains[1:]):
        assert b.start >= a.end - 1e-6

    # (4) at least one fused rollout served both tenants
    assert any("+" in iv.task_id for iv in rollouts), \
        "no cross-task multi-LoRA batching happened"

    # (2) async overlap: some rollout interval overlaps some train interval
    overlap = any(r.start < t.end and t.start < r.end
                  for r in rollouts for t in trains)
    assert overlap, "no rollout/train phase overlap recorded"
